//! The cycle-level simulation driver: connects a stimulus source to any
//! [`SimKernel`] (RTeAAL kernels or baselines), with optional waveform
//! capture and throughput statistics.

use std::time::Instant;

use crate::kernels::SimKernel;
use crate::sim::vcd::VcdWriter;
use crate::tensor::ir::LayerIr;

/// Results of a simulation run.
#[derive(Debug, Clone)]
pub struct SimStats {
    pub cycles: u64,
    pub wall: std::time::Duration,
    /// simulated cycles per second
    pub hz: f64,
}

impl SimStats {
    pub fn khz(&self) -> f64 {
        self.hz / 1e3
    }
}

/// Driver owning a kernel + stimulus.
pub struct Simulator {
    pub kernel: Box<dyn SimKernel>,
    stimulus: Box<dyn FnMut(u64) -> Vec<u64>>,
    vcd: Option<VcdWriter>,
    /// First waveform write failure; sampling stops when set and the
    /// error is reported by [`Simulator::finish`] (the run loops keep
    /// their throughput-only signatures).
    vcd_err: Option<std::io::Error>,
    cycle: u64,
}

impl Simulator {
    pub fn new(kernel: Box<dyn SimKernel>, stimulus: Box<dyn FnMut(u64) -> Vec<u64>>) -> Self {
        Simulator { kernel, stimulus, vcd: None, vcd_err: None, cycle: 0 }
    }

    /// Attach a VCD waveform writer (paper §6.2: optimizations that would
    /// eliminate signals are disabled by the caller compiling with
    /// `optimize_no_fusion` + naming).
    pub fn with_vcd(mut self, ir: &LayerIr, path: &std::path::Path) -> std::io::Result<Self> {
        self.vcd = Some(VcdWriter::create(ir, path)?);
        Ok(self)
    }

    /// Sample the waveform at the current cycle; on a write failure,
    /// record the error and stop sampling (a partial waveform plus a
    /// swallowed error would read as a complete quiescent run).
    fn sample_vcd(&mut self) {
        if let Some(vcd) = &mut self.vcd {
            if let Err(e) = vcd.sample(self.cycle, self.kernel.slots()) {
                self.vcd_err = Some(e);
                self.vcd = None;
            }
        }
    }

    /// Run for `cycles`, returning throughput statistics.
    pub fn run(&mut self, cycles: u64) -> SimStats {
        let t0 = Instant::now();
        for _ in 0..cycles {
            let inputs = (self.stimulus)(self.cycle);
            self.kernel.step(&inputs);
            self.cycle += 1;
            self.sample_vcd();
        }
        let wall = t0.elapsed();
        SimStats { cycles, wall, hz: cycles as f64 / wall.as_secs_f64().max(1e-12) }
    }

    /// Run until `pred(outputs)` is true or `max_cycles` elapse. Returns
    /// the cycle count at which the predicate fired (None on timeout).
    pub fn run_until(
        &mut self,
        max_cycles: u64,
        mut pred: impl FnMut(&[(String, u64)]) -> bool,
    ) -> Option<u64> {
        for _ in 0..max_cycles {
            let inputs = (self.stimulus)(self.cycle);
            self.kernel.step(&inputs);
            self.cycle += 1;
            self.sample_vcd();
            if pred(&self.kernel.outputs()) {
                return Some(self.cycle);
            }
        }
        None
    }

    pub fn outputs(&self) -> Vec<(String, u64)> {
        self.kernel.outputs()
    }

    /// Finish any waveform output, surfacing a write error recorded
    /// mid-run (full disk, closed pipe) as well as flush failures.
    pub fn finish(mut self) -> std::io::Result<()> {
        if let Some(e) = self.vcd_err.take() {
            return Err(e);
        }
        if let Some(vcd) = self.vcd.take() {
            vcd.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;
    use crate::kernels::{build, KernelConfig};
    use crate::tensor::ir::lower;
    use crate::graph::passes::optimize;

    #[test]
    fn runs_counter_design() {
        let d = catalog("counter").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let kernel = build(KernelConfig::PSU, &ir);
        let mut sim = Simulator::new(kernel, d.make_stimulus());
        let stats = sim.run(1000);
        assert_eq!(stats.cycles, 1000);
        assert!(stats.hz > 0.0);
    }

    /// A waveform write failure mid-run surfaces from `finish()` instead
    /// of vanishing (the run itself completes; the error is not lost).
    #[test]
    fn vcd_write_failure_surfaces_from_finish() {
        let full = std::path::Path::new("/dev/full");
        if !full.exists() {
            return; // non-Linux dev environment
        }
        let d = catalog("counter").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let kernel = build(KernelConfig::PSU, &ir);
        let mut sim = Simulator::new(kernel, d.make_stimulus()).with_vcd(&ir, full).unwrap();
        // enough changing samples to overflow the writer's buffer
        let stats = sim.run(20_000);
        assert_eq!(stats.cycles, 20_000, "the run itself still completes");
        assert!(sim.finish().is_err(), "ENOSPC was swallowed");
    }

    #[test]
    fn run_until_tiny_cpu_halts() {
        let d = catalog("tiny_cpu").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let kernel = build(KernelConfig::TI, &ir);
        let mut sim = Simulator::new(kernel, d.make_stimulus());
        let halted = sim.run_until(10_000, |outs| {
            outs.iter().any(|(n, v)| n == "halted" && *v == 1)
        });
        assert!(halted.is_some());
        let prog = crate::designs::tiny_cpu::dhrystone_like(40);
        let (golden, _) = crate::designs::tiny_cpu::golden_run(&prog, 1_000_000);
        let outs: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
        assert_eq!(outs["checksum"], golden as u64);
    }
}
