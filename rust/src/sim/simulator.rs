//! The cycle-level simulation driver: connects a stimulus source to any
//! [`SimKernel`] (RTeAAL kernels or baselines), with optional waveform
//! capture and throughput statistics.

use std::time::Instant;

use crate::kernels::SimKernel;
use crate::sim::vcd::VcdWriter;
use crate::tensor::ir::LayerIr;

/// Results of a simulation run.
#[derive(Debug, Clone)]
pub struct SimStats {
    pub cycles: u64,
    pub wall: std::time::Duration,
    /// simulated cycles per second
    pub hz: f64,
}

impl SimStats {
    pub fn khz(&self) -> f64 {
        self.hz / 1e3
    }
}

/// Driver owning a kernel + stimulus.
pub struct Simulator {
    pub kernel: Box<dyn SimKernel>,
    stimulus: Box<dyn FnMut(u64) -> Vec<u64>>,
    vcd: Option<VcdWriter>,
    cycle: u64,
}

impl Simulator {
    pub fn new(kernel: Box<dyn SimKernel>, stimulus: Box<dyn FnMut(u64) -> Vec<u64>>) -> Self {
        Simulator { kernel, stimulus, vcd: None, cycle: 0 }
    }

    /// Attach a VCD waveform writer (paper §6.2: optimizations that would
    /// eliminate signals are disabled by the caller compiling with
    /// `optimize_no_fusion` + naming).
    pub fn with_vcd(mut self, ir: &LayerIr, path: &std::path::Path) -> std::io::Result<Self> {
        self.vcd = Some(VcdWriter::create(ir, path)?);
        Ok(self)
    }

    /// Run for `cycles`, returning throughput statistics.
    pub fn run(&mut self, cycles: u64) -> SimStats {
        let t0 = Instant::now();
        for _ in 0..cycles {
            let inputs = (self.stimulus)(self.cycle);
            self.kernel.step(&inputs);
            self.cycle += 1;
            if let Some(vcd) = &mut self.vcd {
                vcd.sample(self.cycle, self.kernel.slots());
            }
        }
        let wall = t0.elapsed();
        SimStats { cycles, wall, hz: cycles as f64 / wall.as_secs_f64().max(1e-12) }
    }

    /// Run until `pred(outputs)` is true or `max_cycles` elapse. Returns
    /// the cycle count at which the predicate fired (None on timeout).
    pub fn run_until(
        &mut self,
        max_cycles: u64,
        mut pred: impl FnMut(&[(String, u64)]) -> bool,
    ) -> Option<u64> {
        for _ in 0..max_cycles {
            let inputs = (self.stimulus)(self.cycle);
            self.kernel.step(&inputs);
            self.cycle += 1;
            if let Some(vcd) = &mut self.vcd {
                vcd.sample(self.cycle, self.kernel.slots());
            }
            if pred(&self.kernel.outputs()) {
                return Some(self.cycle);
            }
        }
        None
    }

    pub fn outputs(&self) -> Vec<(String, u64)> {
        self.kernel.outputs()
    }

    /// Finish any waveform output.
    pub fn finish(mut self) -> std::io::Result<()> {
        if let Some(vcd) = self.vcd.take() {
            vcd.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::catalog;
    use crate::kernels::{build, KernelConfig};
    use crate::tensor::ir::lower;
    use crate::graph::passes::optimize;

    #[test]
    fn runs_counter_design() {
        let d = catalog("counter").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let kernel = build(KernelConfig::PSU, &ir);
        let mut sim = Simulator::new(kernel, d.make_stimulus());
        let stats = sim.run(1000);
        assert_eq!(stats.cycles, 1000);
        assert!(stats.hz > 0.0);
    }

    #[test]
    fn run_until_tiny_cpu_halts() {
        let d = catalog("tiny_cpu").unwrap();
        let (opt, _) = optimize(&d.graph);
        let ir = lower(&opt);
        let kernel = build(KernelConfig::TI, &ir);
        let mut sim = Simulator::new(kernel, d.make_stimulus());
        let halted = sim.run_until(10_000, |outs| {
            outs.iter().any(|(n, v)| n == "halted" && *v == 1)
        });
        assert!(halted.is_some());
        let prog = crate::designs::tiny_cpu::dhrystone_like(40);
        let (golden, _) = crate::designs::tiny_cpu::golden_run(&prog, 1_000_000);
        let outs: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
        assert_eq!(outs["checksum"], golden as u64);
    }
}
