//! VCD waveform writer (paper §6.2): every *named* slot becomes a VCD
//! variable; on each sampled cycle only signals whose value changed since
//! the previous cycle are emitted (the change-detection scheme the paper
//! describes).
//!
//! Delta semantics, precisely:
//!
//! * the `#{cycle}` timestamp is **buffered** and written only when at
//!   least one variable changes at that time — a fully quiescent cycle
//!   contributes zero bytes to the file (these are exactly the idle
//!   cycles the activity subsystem skips, so a "delta" VCD of a mostly
//!   idle run stays proportional to the activity, not to the cycle
//!   count);
//! * the **first** sample is a full dump of every variable — there is no
//!   previous-value sentinel, so a signal whose genuine first value is
//!   `u64::MAX` (e.g. the `Not` of a zero input at full width) is dumped
//!   like any other;
//! * emitted values are masked to the variable's declared width, so a
//!   stale high bit in a slot can never leak into the waveform.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::graph::ops::mask;
use crate::tensor::ir::LayerIr;

/// Generic over the byte sink so the same emission code serves files
/// (`BufWriter<File>`, the default — all pre-existing call sites) and
/// in-memory buffers (`Vec<u8>`, the serve waveform-streaming chunks and
/// the byte-identity tests). Byte-identity between the scalar full-diff
/// path and the mask-gated [`crate::sim::wave::WaveSink`] holds because
/// both run exactly this writer's [`Self::record`].
pub struct VcdWriter<W: Write = BufWriter<File>> {
    out: W,
    /// (slot, id string, width)
    vars: Vec<(u32, String, u8)>,
    last: Vec<u64>,
    first: bool,
    /// timestamp of the current sample, written lazily before the first
    /// changed-variable line (quiescent samples emit nothing)
    pending_time: Option<u64>,
    /// per-var value gather scratch for the slot-file entry point
    vals: Vec<u64>,
}

/// VCD identifier codes: printable chars from '!' (33) to '~' (126).
fn id_code(mut n: usize) -> String {
    let mut s = String::new();
    loop {
        s.push((33 + (n % 94)) as u8 as char);
        n /= 94;
        if n == 0 {
            break;
        }
    }
    s
}

impl VcdWriter<BufWriter<File>> {
    /// Writer over every *named* slot of `ir` (the scalar simulator's
    /// waveform: one variable per named signal).
    pub fn create(ir: &LayerIr, path: &Path) -> std::io::Result<Self> {
        Self::new(ir, BufWriter::new(File::create(path)?))
    }

    /// File-backed [`Self::new_outputs`].
    pub fn create_outputs(ir: &LayerIr, path: &Path) -> std::io::Result<Self> {
        Self::new_outputs(ir, BufWriter::new(File::create(path)?))
    }
}

impl<W: Write> VcdWriter<W> {
    /// Writer over every named slot of `ir` into an arbitrary byte sink
    /// (the header is written immediately).
    pub fn new(ir: &LayerIr, out: W) -> std::io::Result<Self> {
        let vars: Vec<(u32, u8, &str)> = ir
            .slot_names
            .iter()
            .enumerate()
            .filter_map(|(slot, name)| {
                name.as_deref().map(|n| (slot as u32, ir.slot_widths[slot], n))
            })
            .collect();
        Self::with_vars(ir, out, &vars)
    }

    /// Writer over the design's **output ports** only, in
    /// `ir.output_slots` order. This is the variable set available from a
    /// partitioned run: internal named slots live in replicated
    /// per-partition cones, but partition 0 computes every design output
    /// by construction, so its committed output-port values are globally
    /// correct. [`Self::sample_values`] pairs with the lane-buffered
    /// `write_lane_outputs` values, which follow the same order.
    pub fn new_outputs(ir: &LayerIr, out: W) -> std::io::Result<Self> {
        let vars: Vec<(u32, u8, &str)> = ir
            .output_slots
            .iter()
            .map(|(name, slot)| (*slot, ir.slot_widths[*slot as usize], name.as_str()))
            .collect();
        Self::with_vars(ir, out, &vars)
    }

    fn with_vars(ir: &LayerIr, mut out: W, wanted: &[(u32, u8, &str)]) -> std::io::Result<Self> {
        writeln!(out, "$date today $end")?;
        writeln!(out, "$version rteaal {} $end", crate::VERSION)?;
        writeln!(out, "$timescale 1ns $end")?;
        writeln!(out, "$scope module {} $end", if ir.name.is_empty() { "top" } else { &ir.name })?;
        let mut vars = Vec::with_capacity(wanted.len());
        for &(slot, width, name) in wanted {
            let code = id_code(vars.len());
            writeln!(out, "$var wire {width} {code} {name} $end")?;
            vars.push((slot, code, width));
        }
        writeln!(out, "$upscope $end")?;
        writeln!(out, "$enddefinitions $end")?;
        let n = vars.len();
        Ok(VcdWriter {
            out,
            vars,
            last: vec![0; n],
            first: true,
            pending_time: None,
            vals: vec![0; n],
        })
    }

    /// Emit changed signals at time `cycle`, reading each variable from
    /// the scalar slot file. A write failure (full disk, closed pipe,
    /// revoked permissions) is reported, not swallowed.
    pub fn sample(&mut self, cycle: u64, slots: &[u64]) -> std::io::Result<()> {
        let mut vals = std::mem::take(&mut self.vals);
        for (i, (slot, _, _)) in self.vars.iter().enumerate() {
            vals[i] = slots[*slot as usize];
        }
        let result = self.sample_values(cycle, &vals);
        self.vals = vals;
        result
    }

    /// Emit changed signals at time `cycle` from pre-gathered values, one
    /// per declared variable (e.g. the value column of a partitioned
    /// run's buffered `write_lane_outputs`). The timestamp is written
    /// only if some variable changed; the first call dumps everything.
    /// Errors surface on the cycle that failed to write (the change flags
    /// for that cycle are already consumed — a caller that retries gets a
    /// waveform with that cycle's deltas dropped, so callers should stop
    /// sampling on the first error).
    pub fn sample_values(&mut self, cycle: u64, values: &[u64]) -> std::io::Result<()> {
        debug_assert_eq!(values.len(), self.vars.len());
        self.begin_sample(cycle);
        for i in 0..self.vars.len() {
            self.record(i, values[i])?;
        }
        self.end_sample();
        Ok(())
    }

    /// Start a sample at time `cycle`. The timestamp is buffered: it is
    /// written only if a subsequent [`Self::record`] emits a value.
    pub fn begin_sample(&mut self, cycle: u64) {
        self.pending_time = Some(cycle);
    }

    /// Compare-and-emit one variable. Callers must visit variables in
    /// ascending index order within a sample (declaration order — the
    /// order [`Self::sample_values`] uses), and between
    /// [`Self::begin_sample`] and [`Self::end_sample`]. Skipping an index
    /// whose value is unchanged produces byte-identical output to
    /// recording it — this is the contract the mask-gated
    /// [`crate::sim::wave::WaveSink`] is built on.
    pub fn record(&mut self, i: usize, value: u64) -> std::io::Result<()> {
        let (_, ref code, width) = self.vars[i];
        let v = value & mask(width);
        if self.first || self.last[i] != v {
            self.last[i] = v;
            if let Some(t) = self.pending_time.take() {
                writeln!(self.out, "#{t}")?;
            }
            if width == 1 {
                writeln!(self.out, "{}{}", v & 1, code)?;
            } else {
                writeln!(self.out, "b{:b} {}", v, code)?;
            }
        }
        Ok(())
    }

    /// Close the current sample. After the first sample completes, the
    /// writer switches from full-dump to delta mode.
    pub fn end_sample(&mut self) {
        self.first = false;
    }

    /// True until the first sample has completed — that sample must visit
    /// every variable (the full dump).
    pub fn is_first(&self) -> bool {
        self.first
    }

    /// The declared variables: `(slot, id code, width)` in declaration
    /// order. Index `i` here is the `i` accepted by [`Self::record`].
    pub fn vars(&self) -> &[(u32, String, u8)] {
        &self.vars
    }

    /// The underlying byte sink (e.g. to drain a `Vec<u8>`-backed
    /// writer's accumulated bytes as a streaming chunk).
    pub fn writer_mut(&mut self) -> &mut W {
        &mut self.out
    }

    pub fn finish(mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::simple::counter;
    use crate::tensor::ir::{lower, IrSim};

    #[test]
    fn writes_valid_vcd_with_change_detection() {
        let g = counter(4);
        let ir = lower(&g);
        let dir = std::env::temp_dir().join("rteaal_vcd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("counter.vcd");
        let mut w = VcdWriter::create(&ir, &path).unwrap();
        let mut sim = IrSim::new(ir);
        for c in 1..=4u64 {
            sim.step(&[1, 0]);
            w.sample(c, &sim.slots).unwrap();
        }
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("$enddefinitions"));
        assert!(text.contains("$var wire 4"));
        assert!(text.contains("#1"));
        assert!(text.contains("#4"));
        // count changes every cycle: 4 samples emit 4 values for it
        let count_lines = text.lines().filter(|l| l.starts_with('b')).count();
        assert!(count_lines >= 4, "{text}");
    }

    #[test]
    fn id_codes_unique() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..500 {
            assert!(seen.insert(id_code(i)));
        }
    }

    /// A fully quiescent sample contributes nothing — not even its
    /// timestamp (the delta-bloat bug: `#N` lines on exactly the idle
    /// cycles the activity subsystem skips).
    #[test]
    fn quiescent_cycles_emit_no_timestamp() {
        let g = counter(4);
        let ir = lower(&g);
        let dir = std::env::temp_dir().join("rteaal_vcd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("quiescent.vcd");
        let mut w = VcdWriter::create(&ir, &path).unwrap();
        let mut sim = IrSim::new(ir);
        sim.step(&[0, 0]); // enable low: the counter holds its value
        w.sample(1, &sim.slots).unwrap(); // first sample: full dump at #1
        w.sample(2, &sim.slots).unwrap(); // same state re-sampled: nothing changes
        w.sample(3, &sim.slots).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("#1"), "{text}");
        assert!(!text.contains("#2"), "quiescent cycle leaked a timestamp: {text}");
        assert!(!text.contains("#3"), "quiescent cycle leaked a timestamp: {text}");
    }

    /// No first-sample sentinel: a 64-bit signal whose genuine first
    /// value is `u64::MAX` is dumped like any other (the old
    /// `last = u64::MAX` initialization silently swallowed it).
    #[test]
    fn first_sample_dumps_u64_max_values() {
        use crate::graph::ops::PrimOp;
        let mut g = crate::graph::Graph::new("allones");
        let a = g.input("a", 64);
        let x = g.prim(PrimOp::Not, &[a]);
        g.output("y", x);
        let ir = lower(&g);
        let dir = std::env::temp_dir().join("rteaal_vcd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("allones.vcd");
        let mut w = VcdWriter::create(&ir, &path).unwrap();
        let mut sim = IrSim::new(ir);
        sim.step(&[0]); // !0 = u64::MAX on the 64-bit output
        w.sample(1, &sim.slots).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let ones = "1".repeat(64);
        assert!(
            text.lines().any(|l| l.starts_with(&format!("b{ones} "))),
            "first-value u64::MAX dump missing: {text}"
        );
    }

    /// Emitted values are masked to the declared width: a stale high bit
    /// planted in the slot file cannot leak into the waveform.
    #[test]
    fn emitted_values_masked_to_declared_width() {
        let g = counter(4);
        let ir = lower(&g);
        let dir = std::env::temp_dir().join("rteaal_vcd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("masked.vcd");
        let mut w = VcdWriter::create(&ir, &path).unwrap();
        let mut sim = IrSim::new(ir);
        sim.step(&[1, 0]);
        let mut slots = sim.slots.clone();
        for s in slots.iter_mut() {
            *s |= 0xFFFF_FFFF_FFFF_FF00; // garbage above any declared width
        }
        w.sample(1, &slots).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines().filter(|l| l.starts_with('b')) {
            let bits = line[1..].split(' ').next().unwrap();
            assert!(bits.len() <= 4, "value wider than declared width: {line}");
        }
    }

    /// An unwritable target fails at creation with an `Err`, not later
    /// or never (the old writer's only creation-time error path).
    #[test]
    fn unwritable_path_is_a_creation_error() {
        let g = counter(4);
        let ir = lower(&g);
        let err = VcdWriter::create(&ir, Path::new("/nonexistent_rteaal_dir/x.vcd"));
        assert!(err.is_err());
    }

    /// Write failures *during* sampling are reported instead of being
    /// swallowed (the satellite fix: the old `sample` discarded them,
    /// so a full disk produced a silently truncated waveform). `/dev/full`
    /// accepts the buffered header, then fails with `ENOSPC` once the
    /// writer's buffer first drains mid-run.
    #[test]
    fn write_failure_during_sampling_is_reported() {
        let full = Path::new("/dev/full");
        if !full.exists() {
            return; // non-Linux dev environment
        }
        let g = counter(16);
        let ir = lower(&g);
        let mut w = VcdWriter::create(&ir, full).unwrap();
        let mut sim = IrSim::new(ir);
        let mut failed = false;
        // enough always-changing samples to overflow the 8 KiB buffer
        for c in 1..=8_000u64 {
            sim.step(&[1, 0]);
            if w.sample(c, &sim.slots).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "ENOSPC never surfaced through sample()");
    }

    /// The outputs-only writer declares exactly the design's output ports
    /// and samples from a plain value column.
    #[test]
    fn outputs_writer_declares_ports_and_buffers_timestamps() {
        let g = counter(4);
        let ir = lower(&g);
        let dir = std::env::temp_dir().join("rteaal_vcd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("outputs.vcd");
        let n_outputs = ir.output_slots.len();
        let mut w = VcdWriter::create_outputs(&ir, &path).unwrap();
        let threes = vec![3u64; n_outputs];
        let fives = vec![5u64; n_outputs];
        w.sample_values(1, &threes).unwrap(); // full dump
        w.sample_values(2, &threes).unwrap(); // quiescent
        w.sample_values(3, &fives).unwrap(); // change
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let declared = text.lines().filter(|l| l.starts_with("$var")).count();
        assert_eq!(declared, n_outputs, "{text}");
        assert!(text.contains("#1"), "{text}");
        assert!(!text.contains("#2"), "{text}");
        assert!(text.contains("#3"), "{text}");
    }
}
