//! VCD waveform writer (paper §6.2): every *named* slot becomes a VCD
//! variable; on each sampled cycle only signals whose value changed since
//! the previous cycle are emitted (the change-detection scheme the paper
//! describes).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::tensor::ir::LayerIr;

pub struct VcdWriter {
    out: BufWriter<File>,
    /// (slot, id string, width)
    vars: Vec<(u32, String, u8)>,
    last: Vec<u64>,
    first: bool,
}

/// VCD identifier codes: printable chars from '!' (33) to '~' (126).
fn id_code(mut n: usize) -> String {
    let mut s = String::new();
    loop {
        s.push((33 + (n % 94)) as u8 as char);
        n /= 94;
        if n == 0 {
            break;
        }
    }
    s
}

impl VcdWriter {
    pub fn create(ir: &LayerIr, path: &Path) -> std::io::Result<Self> {
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "$date today $end")?;
        writeln!(out, "$version rteaal {} $end", crate::VERSION)?;
        writeln!(out, "$timescale 1ns $end")?;
        writeln!(out, "$scope module {} $end", if ir.name.is_empty() { "top" } else { &ir.name })?;
        let mut vars = Vec::new();
        for (slot, name) in ir.slot_names.iter().enumerate() {
            if let Some(name) = name {
                let code = id_code(vars.len());
                let width = ir.slot_widths[slot];
                writeln!(out, "$var wire {width} {code} {name} $end")?;
                vars.push((slot as u32, code, width));
            }
        }
        writeln!(out, "$upscope $end")?;
        writeln!(out, "$enddefinitions $end")?;
        Ok(VcdWriter { out, vars, last: Vec::new(), first: true })
    }

    /// Emit changed signals at time `cycle`.
    pub fn sample(&mut self, cycle: u64, slots: &[u64]) {
        let _ = writeln!(self.out, "#{cycle}");
        if self.first {
            self.first = false;
            self.last = vec![u64::MAX; self.vars.len()];
        }
        for (i, (slot, code, width)) in self.vars.iter().enumerate() {
            let v = slots[*slot as usize];
            if self.last[i] != v {
                self.last[i] = v;
                if *width == 1 {
                    let _ = writeln!(self.out, "{}{}", v & 1, code);
                } else {
                    let _ = writeln!(self.out, "b{:b} {}", v, code);
                }
            }
        }
    }

    pub fn finish(mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::simple::counter;
    use crate::tensor::ir::{lower, IrSim};

    #[test]
    fn writes_valid_vcd_with_change_detection() {
        let g = counter(4);
        let ir = lower(&g);
        let dir = std::env::temp_dir().join("rteaal_vcd_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("counter.vcd");
        let mut w = VcdWriter::create(&ir, &path).unwrap();
        let mut sim = IrSim::new(ir);
        for c in 1..=4u64 {
            sim.step(&[1, 0]);
            w.sample(c, &sim.slots);
        }
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("$enddefinitions"));
        assert!(text.contains("$var wire 4"));
        assert!(text.contains("#1"));
        assert!(text.contains("#4"));
        // count changes every cycle: 4 samples emit 4 values for it
        let count_lines = text.lines().filter(|l| l.starts_with('b')).count();
        assert!(count_lines >= 4, "{text}");
    }

    #[test]
    fn id_codes_unique() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..500 {
            assert!(seen.insert(id_code(i)));
        }
    }
}
