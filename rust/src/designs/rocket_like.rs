//! RocketChip-like synthetic SoC generator.
//!
//! Each "core" is a 5-stage-pipeline-shaped cluster: fetch/decode mux
//! ladders, a regfile bank with decoded writes, ALU cones, bypass
//! plumbing, and a small CSR-ish bank; cores share an interconnect xor/mux
//! tree. At `scale = 1.0` a core carries ≈60 K effectual ops (paper
//! Table 1, Rocket-1c); the default benches use `scale = 0.1`.

use crate::graph::builder::adapt_width;
use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeId};
use crate::util::prng::Rng;

use super::synth;

pub fn rocket_like(cores: usize, scale: f64) -> Graph {
    let mut g = Graph::new(&format!("rocket_like_{cores}c"));
    let mut rng = Rng::new(0x0C0DE + cores as u64);
    // external stimulus
    let irq = g.input("irq", 4);
    let io_in = g.input("io_in", 32);

    // per-core clusters; cross-core values flow through `bus`
    let mut bus: Vec<NodeId> = vec![io_in, irq];
    // Work per core: the unit block below contributes ~35 effectual ops
    // post-optimization; 60K * scale / 115 blocks per core.
    let blocks = ((60_000.0 * scale) / 35.0).max(1.0) as usize;
    for core in 0..cores {
        let core_out = build_core(&mut g, &mut rng, core, blocks, &bus);
        bus.push(core_out);
    }
    // interconnect: xor-reduce the bus and expose it
    let mut acc = adapt_width(&mut g, bus[0], 32);
    for &b in &bus[1..] {
        let bb = adapt_width(&mut g, b, 32);
        acc = g.prim(PrimOp::Xor, &[acc, bb]);
    }
    let out_reg = g.reg("bus_out", 32, 0);
    g.connect_reg(out_reg, acc);
    g.output("bus_out", out_reg);
    g
}

fn build_core(g: &mut Graph, rng: &mut Rng, core: usize, blocks: usize, bus: &[NodeId]) -> NodeId {
    // architectural state. Blocks read only from `state` (registers +
    // inputs), which bounds the combinational depth per cycle like a real
    // pipeline, and every block's logic feeds its stage register, so
    // nothing is dead.
    let pc = g.reg(&format!("c{core}_pc"), 32, 0x8000_0000);
    let mut state: Vec<NodeId> = vec![pc];
    state.extend_from_slice(bus);

    // regfile: 16 x 32 with decoded write
    let wen = take_bit(g, rng, &state);
    let waddr = take_bits(g, rng, &state, 4);
    let rf = synth::reg_bank(g, &format!("c{core}_rf"), 16, 32, wen, waddr, pc);
    let raddr = take_bits(g, rng, &state, 4);
    let rs1 = synth::bank_read(g, &rf, raddr);

    let mut stage_val = rs1;
    for b in 0..blocks {
        // decode-ish mux ladder (ladders fuse into MuxChain)
        let sels: Vec<NodeId> = (0..6).map(|_| take_bit(g, rng, &state)).collect();
        let mut vals: Vec<NodeId> = (0..6).map(|_| *rng.pick(&state)).collect();
        vals.push(stage_val);
        let decoded = synth::mux_ladder(g, rng, &sels, &vals, 32);

        // ALU cone over the decoded value
        let a = *rng.pick(&state);
        let outs = synth::alu_cone(g, rng, a, decoded, 32);

        // bypass plumbing
        let p = synth::plumbing(g, rng, decoded);

        // fold everything into the stage register via a balanced xor tree
        // (keeps all block logic live and the layer depth bounded)
        let mut leaves: Vec<NodeId> = Vec::with_capacity(outs.len() + p.len() + 1);
        leaves.push(decoded);
        for &o in outs.iter().chain(p.iter()) {
            leaves.push(adapt_width(g, o, 32));
        }
        while leaves.len() > 1 {
            let mut next = Vec::with_capacity(leaves.len().div_ceil(2));
            for pair in leaves.chunks(2) {
                if pair.len() == 2 {
                    let x = adapt_width(g, pair[0], 32);
                    let y = adapt_width(g, pair[1], 32);
                    next.push(g.prim(PrimOp::Xor, &[x, y]));
                } else {
                    next.push(pair[0]);
                }
            }
            leaves = next;
        }
        let sreg = g.reg(&format!("c{core}_s{b}"), 32, 0);
        g.connect_reg(sreg, leaves[0]);
        state.push(sreg);
        stage_val = sreg;
    }

    // pc update: branch muxing
    let taken = take_bit(g, rng, &state);
    let four = g.konst(4, 32);
    let seq = g.prim_w(PrimOp::Add, &[pc, four], 32);
    let target = adapt_width(g, stage_val, 32);
    let pc_next = g.prim(PrimOp::Mux, &[taken, target, seq]);
    g.connect_reg(pc, pc_next);

    // core output: condensed state over *all* stage registers, so every
    // block stays live through the bus regardless of random picks
    let mut acc = adapt_width(g, rs1, 32);
    for &s in state.iter().skip(1 + bus.len()) {
        let sv = adapt_width(g, s, 32);
        acc = g.prim(PrimOp::Xor, &[acc, sv]);
    }
    acc
}

fn take_bit(g: &mut Graph, rng: &mut Rng, pool: &[NodeId]) -> NodeId {
    let src = *rng.pick(pool);
    if g.width(src) == 1 {
        src
    } else {
        let bit = rng.index(g.width(src) as usize) as u8;
        g.prim(PrimOp::Bits(bit, bit), &[src])
    }
}

fn take_bits(g: &mut Graph, rng: &mut Rng, pool: &[NodeId], w: u8) -> NodeId {
    let src = *rng.pick(pool);
    adapt_width(g, src, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::optimize;
    use crate::graph::levelize::levelize;

    #[test]
    fn has_rocket_like_statistics() {
        let g = rocket_like(1, 0.1);
        assert!(g.validate().is_empty());
        let (opt, _) = optimize(&g);
        let ops = opt.num_ops();
        // ~6K effectual ops at scale 0.1 (Table 1 Rocket-1c / 10)
        assert!((3_000..12_000).contains(&ops), "ops {ops}");
        // identity ratio in the paper's ballpark (Table 1: ~5-10x)
        let lv = levelize(&opt);
        let ratio = lv.identity_ops as f64 / lv.effectual_ops() as f64;
        assert!(ratio > 2.0, "identity ratio {ratio}");
        // deep enough to be interesting
        assert!(lv.depth() > 10, "depth {}", lv.depth());
    }

    #[test]
    fn deterministic_generation() {
        let a = rocket_like(2, 0.05);
        let b = rocket_like(2, 0.05);
        assert_eq!(a.nodes.len(), b.nodes.len());
        assert_eq!(a.regs.len(), b.regs.len());
    }
}
