//! Small real designs for quickstarts, docs and tests.

use crate::graph::ops::PrimOp;
use crate::graph::Graph;

/// An `width`-bit counter with enable and synchronous clear.
pub fn counter(width: u8) -> Graph {
    let mut g = Graph::new("counter");
    let en = g.input("en", 1);
    let clr = g.input("clr", 1);
    let r = g.reg("count", width, 0);
    let one = g.konst(1, width);
    let zero = g.konst(0, width);
    let inc = g.prim_w(PrimOp::Add, &[r, one], width);
    let kept = g.prim(PrimOp::Mux, &[en, inc, r]);
    let nxt = g.prim(PrimOp::Mux, &[clr, zero, kept]);
    g.connect_reg(r, nxt);
    g.output("count", r);
    g
}

/// One ALU datapath: op-select mux ladder over
/// add/sub/and/or/xor/shift/compare of `a` and `b`.
fn alu_select(
    g: &mut Graph,
    a: crate::graph::NodeId,
    b: crate::graph::NodeId,
    op: crate::graph::NodeId,
    width: u8,
) -> crate::graph::NodeId {
    let add = g.prim_w(PrimOp::Add, &[a, b], width);
    let sub = g.prim_w(PrimOp::Sub, &[a, b], width);
    let and = g.prim(PrimOp::And, &[a, b]);
    let or = g.prim(PrimOp::Or, &[a, b]);
    let xor = g.prim(PrimOp::Xor, &[a, b]);
    let shl = g.prim_w(PrimOp::Dshl, &[a, b], width);
    let shr = g.prim(PrimOp::Dshr, &[a, b]);
    let ltw = g.prim(PrimOp::Lt, &[a, b]);
    let lt = g.prim_w(PrimOp::Pad(width), &[ltw], width);

    // 3-bit op select: a mux ladder (gets fused to a MuxChain)
    let candidates = [add, sub, and, or, xor, shl, shr, lt];
    let mut sel = candidates[7];
    for (i, &c) in candidates.iter().enumerate().take(7).rev() {
        let k = g.konst(i as u64, 3);
        let eq = g.prim(PrimOp::Eq, &[op, k]);
        sel = g.prim(PrimOp::Mux, &[eq, c, sel]);
    }
    crate::graph::builder::adapt_width(g, sel, width)
}

/// A registered ALU: op-select over add/sub/and/or/xor/shift/compare.
pub fn alu(width: u8) -> Graph {
    let mut g = Graph::new("alu");
    let a = g.input("a", width);
    let b = g.input("b", width);
    let op = g.input("op", 3);
    let r = g.reg("result", width, 0);
    let sel = alu_select(&mut g, a, b, op, width);
    g.connect_reg(r, sel);
    g.output("result", r);
    g
}

/// `blocks` independent registered ALUs, each with its own operand and
/// op-select inputs. The lane-level dynamic-sparsity workload: the design
/// is shallow (latency 2 cycles from input to settled state), so under a
/// low per-lane toggle rate whole lanes are quiescent almost every cycle
/// and the sparse batched executors skip nearly everything, while the
/// design itself scales to an arbitrary op count (`benches/fig23_sparse.rs`).
pub fn alu_farm(blocks: usize, width: u8) -> Graph {
    assert!(blocks >= 1);
    let mut g = Graph::new("alu_farm");
    // declare all ports first, block-major, so port order is stable
    let mut ports = Vec::with_capacity(blocks);
    for k in 0..blocks {
        let a = g.input(&format!("a{k}"), width);
        let b = g.input(&format!("b{k}"), width);
        let op = g.input(&format!("op{k}"), 3);
        ports.push((a, b, op));
    }
    for (k, &(a, b, op)) in ports.iter().enumerate() {
        let r = g.reg(&format!("res{k}"), width, 0);
        let sel = alu_select(&mut g, a, b, op, width);
        g.connect_reg(r, sel);
        g.output(&format!("y{k}"), r);
    }
    g
}

/// An `taps`-tap FIR filter over `width`-bit samples (shift register +
/// constant multipliers + adder tree).
pub fn fir(taps: usize, width: u8) -> Graph {
    let mut g = Graph::new("fir");
    let x = g.input("x", width);
    // delay line
    let mut regs = Vec::with_capacity(taps);
    for i in 0..taps {
        regs.push(g.reg(&format!("z{i}"), width, 0));
    }
    g.connect_reg(regs[0], x);
    for i in 1..taps {
        g.connect_reg(regs[i], regs[i - 1]);
    }
    // coefficient multiply + reduce (coefficients 1,3,5,...)
    let mut terms = Vec::with_capacity(taps);
    for (i, &z) in regs.iter().enumerate() {
        let c = g.konst((2 * i + 1) as u64 & ((1 << 6) - 1), 6);
        let m = g.prim_w(PrimOp::Mul, &[z, c], width);
        terms.push(m);
    }
    let mut acc = terms[0];
    for &t in &terms[1..] {
        acc = g.prim_w(PrimOp::Add, &[acc, t], width);
    }
    g.output("y", acc);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RefSim;

    #[test]
    fn counter_with_clear() {
        let mut sim = RefSim::new(counter(8));
        for _ in 0..5 {
            sim.step(&[1, 0]);
        }
        assert_eq!(sim.outputs()[0].1, 5);
        sim.step(&[1, 1]); // clear wins
        assert_eq!(sim.outputs()[0].1, 0);
    }

    #[test]
    fn alu_ops() {
        let mut sim = RefSim::new(alu(16));
        sim.step(&[7, 5, 0]); // add
        assert_eq!(sim.outputs()[0].1, 12);
        sim.step(&[7, 5, 1]); // sub
        assert_eq!(sim.outputs()[0].1, 2);
        sim.step(&[0b1100, 0b1010, 2]); // and
        assert_eq!(sim.outputs()[0].1, 0b1000);
        sim.step(&[3, 5, 7]); // lt
        assert_eq!(sim.outputs()[0].1, 1);
    }

    #[test]
    fn alu_farm_blocks_are_independent() {
        let mut sim = RefSim::new(alu_farm(3, 16));
        // block 0: 7 + 5, block 1: 9 - 4, block 2: 6 & 3
        sim.step(&[7, 5, 0, 9, 4, 1, 6, 3, 2]);
        assert_eq!(sim.outputs()[0].1, 12);
        assert_eq!(sim.outputs()[1].1, 5);
        assert_eq!(sim.outputs()[2].1, 2);
    }

    #[test]
    fn fir_impulse_response() {
        let mut sim = RefSim::new(fir(4, 16));
        // impulse: first sample 1, then zeros -> outputs = coefficients
        sim.step(&[1]);
        let mut seen = Vec::new();
        for _ in 0..4 {
            sim.step(&[0]);
            seen.push(sim.outputs()[0].1);
        }
        assert_eq!(seen, vec![1, 3, 5, 7]);
    }
}
