//! RTL designs (substitutes for the paper's Chipyard designs, §7.1).
//!
//! The paper evaluates RocketChip, SmallBOOM, Gemmini and SHA3 from
//! Chipyard — multi-MB FIRRTL we cannot regenerate here. Instead:
//!
//! * [`rocket_like`] / [`boom_like`] — parameterized synthetic generators
//!   reproducing the *statistics* the paper's phenomena depend on (op mix,
//!   mux-chain density, layer shape, fanout, identity-op ratio per
//!   Table 1), with a `cores` knob for the r1–r24 scaling studies. The
//!   default `scale` is 1/10 of the real designs so benches stay fast;
//!   everything scales linearly.
//! * [`gemmini_like`] — a real weight-stationary systolic MAC array.
//! * [`keccak`] — a *real* Keccak-f[1600] round datapath (the SHA3 role),
//!   validated against a software Keccak.
//! * [`tiny_cpu`] — a real 32-bit RISC-style CPU with ROM/RAM/regfile that
//!   executes a dhrystone-like mixed-op program to completion
//!   (checksum-verified) — the end-to-end workload.
//! * [`simple`] — counters/ALUs/FIR for quickstarts and docs.
//!
//! [`catalog`] maps design names (`rocket_like_1c`, …) to built designs
//! with their default workloads (paper Table 3 analog).

pub mod simple;
pub mod synth;
pub mod rocket_like;
pub mod boom_like;
pub mod gemmini_like;
pub mod keccak;
pub mod tiny_cpu;

use crate::graph::Graph;
use crate::kernels::BatchKernel;
use crate::util::prng::Rng;

/// How a design is driven during benchmarking.
pub enum Stimulus {
    /// Pseudo-random inputs from a fixed seed.
    Random(u64),
    /// All-zero inputs (design is self-driving, e.g. tiny_cpu).
    Zero,
}

/// A named design plus its default workload.
pub struct Design {
    pub name: String,
    pub graph: Graph,
    pub stimulus: Stimulus,
    /// Default simulated cycles for headline runs (Table 3 analog).
    pub default_cycles: u64,
    /// Divergent-lane initialization: (register name, per-lane values).
    /// Lane `l` of a batched run starts the named register at
    /// `values[l % values.len()]` instead of the graph's init value (see
    /// [`Design::apply_lane_init`]); e.g. per-lane instruction ROMs for
    /// [`tiny_cpu::tiny_cpu_divergent`]. Empty for ordinary designs.
    pub lane_init: Vec<(String, Vec<u64>)>,
}

/// Deterministic per-lane stimulus seed: lane 0 keeps the design's base
/// seed (so a 1-lane batched run replays the single-lane stimulus), later
/// lanes decorrelate via a golden-ratio stride through seed space.
pub fn lane_seed(seed: u64, lane: usize) -> u64 {
    seed.wrapping_add((lane as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

impl Design {
    /// Produce the input vector for a cycle.
    pub fn make_stimulus(&self) -> Box<dyn FnMut(u64) -> Vec<u64>> {
        let n_inputs = self.graph.inputs.len();
        let widths: Vec<u8> = self.graph.inputs.iter().map(|p| p.width).collect();
        match self.stimulus {
            Stimulus::Random(seed) => {
                let mut rng = Rng::new(seed);
                Box::new(move |_cycle| widths.iter().map(|&w| rng.bits(w)).collect())
            }
            Stimulus::Zero => Box::new(move |_cycle| vec![0u64; n_inputs]),
        }
    }

    /// The single-lane stimulus stream of one batch lane (used to replay a
    /// batched lane on a scalar kernel).
    pub fn make_stimulus_for_lane(&self, lane: usize) -> Box<dyn FnMut(u64) -> Vec<u64>> {
        let n_inputs = self.graph.inputs.len();
        let widths: Vec<u8> = self.graph.inputs.iter().map(|p| p.width).collect();
        match self.stimulus {
            Stimulus::Random(seed) => {
                let mut rng = Rng::new(lane_seed(seed, lane));
                Box::new(move |_cycle| widths.iter().map(|&w| rng.bits(w)).collect())
            }
            Stimulus::Zero => Box::new(move |_cycle| vec![0u64; n_inputs]),
        }
    }

    /// Produce lane-major input vectors for a `lanes`-wide batched run:
    /// the result has `inputs[i * lanes + lane]` = input port `i` of
    /// `lane`. Lane `l`'s stream equals [`Design::make_stimulus_for_lane`]
    /// with the same `l` (and lane 0 equals [`Design::make_stimulus`]).
    pub fn make_lane_stimulus(&self, lanes: usize) -> Box<dyn FnMut(u64) -> Vec<u64>> {
        assert!(lanes >= 1);
        let n_inputs = self.graph.inputs.len();
        let widths: Vec<u8> = self.graph.inputs.iter().map(|p| p.width).collect();
        match self.stimulus {
            Stimulus::Random(seed) => {
                let mut rngs: Vec<Rng> =
                    (0..lanes).map(|l| Rng::new(lane_seed(seed, l))).collect();
                Box::new(move |_cycle| {
                    let mut out = vec![0u64; widths.len() * lanes];
                    for (l, rng) in rngs.iter_mut().enumerate() {
                        for (i, &w) in widths.iter().enumerate() {
                            out[i * lanes + l] = rng.bits(w);
                        }
                    }
                    out
                })
            }
            Stimulus::Zero => Box::new(move |_cycle| vec![0u64; n_inputs * lanes]),
        }
    }

    /// Toggle-rate-controlled lane-major stimulus: each lane draws a
    /// random input vector on cycle 0, then *holds* it; with probability
    /// `rate` per (lane, cycle) the lane's inputs change — every port is
    /// XOR-ed with a random nonzero delta, so a toggling lane is
    /// guaranteed to actually change on every port. `rate = 1.0` toggles
    /// every lane every cycle; `rate = 0.0` freezes the stimulus after
    /// cycle 0 (the idle workload). Lanes toggle independently with
    /// decorrelated seeds. This is the dynamic-sparsity knob driving the
    /// sparse activity-masked executors (`benches/fig23_sparse.rs`).
    pub fn make_lane_stimulus_toggle(
        &self,
        lanes: usize,
        rate: f64,
    ) -> Box<dyn FnMut(u64) -> Vec<u64>> {
        assert!(lanes >= 1);
        assert!((0.0..=1.0).contains(&rate), "toggle rate must be in [0, 1] (got {rate})");
        let n_inputs = self.graph.inputs.len();
        let widths: Vec<u8> = self.graph.inputs.iter().map(|p| p.width).collect();
        match self.stimulus {
            Stimulus::Random(seed) => {
                let mut rngs: Vec<Rng> =
                    (0..lanes).map(|l| Rng::new(lane_seed(seed, l))).collect();
                // lane-major held values, prev[i * lanes + l]
                let mut prev = vec![0u64; n_inputs * lanes];
                let mut started = false;
                Box::new(move |_cycle| {
                    if !started {
                        started = true;
                        for (l, rng) in rngs.iter_mut().enumerate() {
                            for (i, &w) in widths.iter().enumerate() {
                                prev[i * lanes + l] = rng.bits(w);
                            }
                        }
                    } else {
                        for (l, rng) in rngs.iter_mut().enumerate() {
                            if rng.chance(rate) {
                                for (i, &w) in widths.iter().enumerate() {
                                    // nonzero delta: bit 0 always flips
                                    prev[i * lanes + l] ^= rng.bits(w) | 1;
                                }
                            }
                        }
                    }
                    prev.clone()
                })
            }
            Stimulus::Zero => Box::new(move |_cycle| vec![0u64; n_inputs * lanes]),
        }
    }

    /// Resolve this design's divergent-lane initialization to concrete
    /// `(slot, lane, value)` pokes. `compiled_graph` must be the
    /// *optimized* graph the kernel was lowered from (its node ids are
    /// the slot ids); registers are resolved by name, which survives
    /// every pass. Consumers that are not a single [`BatchKernel`] — the
    /// partitioned [`crate::coordinator::parallel::BatchParallelSim`],
    /// per-lane reference interpreters — replay these pokes themselves.
    pub fn resolved_lane_init(
        &self,
        compiled_graph: &Graph,
        lanes: usize,
    ) -> Vec<(u32, usize, u64)> {
        let mut pokes = Vec::new();
        for (name, values) in &self.lane_init {
            assert!(!values.is_empty(), "lane_init for '{name}' has no values");
            let reg = compiled_graph.regs.iter().find(|r| r.name == *name).unwrap_or_else(|| {
                panic!("lane_init: no register named '{name}' in {}", self.name)
            });
            let m = crate::graph::ops::mask(reg.width);
            for l in 0..lanes {
                pokes.push((reg.node, l, values[l % values.len()] & m));
            }
        }
        pokes
    }

    /// Apply this design's divergent-lane initialization to a freshly
    /// built batched kernel (see [`Design::resolved_lane_init`]).
    pub fn apply_lane_init(&self, compiled_graph: &Graph, kernel: &mut dyn BatchKernel) {
        for (slot, lane, value) in self.resolved_lane_init(compiled_graph, kernel.lanes()) {
            kernel.poke_lane(slot, lane, value);
        }
    }
}

/// Build a design by name. Names: `counter`, `alu32`, `fir8`, `keccak`,
/// `tiny_cpu`, `gemmini_like_{4,8,16}`, `rocket_like_{1,2,4,8,12,16,20,24}c`,
/// `boom_like_{1,2,4,8}c`, `alu_farm_N` (N independent registered ALU
/// blocks — the lane-sparsity workload for `--sparse` benchmarking),
/// plus `rocket_like_xs` (export-sized).
pub fn catalog(name: &str) -> Option<Design> {
    let d = match name {
        "counter" => Design {
            name: name.into(),
            graph: simple::counter(16),
            stimulus: Stimulus::Random(1),
            default_cycles: 10_000,
            lane_init: vec![],
        },
        "alu32" => Design {
            name: name.into(),
            graph: simple::alu(32),
            stimulus: Stimulus::Random(2),
            default_cycles: 10_000,
            lane_init: vec![],
        },
        "fir8" => Design {
            name: name.into(),
            graph: simple::fir(8, 16),
            stimulus: Stimulus::Random(3),
            default_cycles: 10_000,
            lane_init: vec![],
        },
        "keccak" => Design {
            name: name.into(),
            graph: keccak::keccak_round_datapath(),
            stimulus: Stimulus::Random(4),
            // paper Table 3: SHA3 runs 1.2M cycles; scaled 1/10
            default_cycles: 120_000,
            lane_init: vec![],
        },
        "tiny_cpu" => Design {
            name: name.into(),
            graph: tiny_cpu::tiny_cpu(&tiny_cpu::dhrystone_like(40)),
            stimulus: Stimulus::Zero,
            default_cycles: 8_000,
            lane_init: vec![],
        },
        // the divergent-lane variant: register-file ROM, one program per
        // lane (lane l runs programs[l % 2]) — the design whose lane_init
        // actually diverges, so batched/service runs exercise the
        // per-lane initialization path end to end
        "tiny_cpu_divergent" => {
            let prog_a = tiny_cpu::dhrystone_like(12);
            let prog_b = tiny_cpu::dhrystone_like(7);
            let rom_words = 32;
            Design {
                name: name.into(),
                graph: tiny_cpu::tiny_cpu_divergent(rom_words, &prog_a),
                stimulus: Stimulus::Zero,
                default_cycles: 4_000,
                lane_init: tiny_cpu::lane_rom_init(rom_words, &[prog_a, prog_b]),
            }
        }
        _ => {
            // "<base>_edit": the canonical incremental-compile workload —
            // the base design with one module's next-state function
            // modified, every other cone bit-identical. The *graph* name
            // is left untouched so the edited design stays in the same
            // cache family as its base (see
            // `service::cache::DesignCache::open_design_incremental`).
            if let Some(base) = name.strip_suffix("_edit") {
                let mut d = catalog(base)?;
                apply_module_edit(&mut d.graph);
                d.name = name.into();
                return Some(d);
            }
            if let Some(rest) = name.strip_prefix("rocket_like_") {
                if rest == "xs" {
                    // small export-sized variant for the XLA backend
                    return Some(Design {
                        name: name.into(),
                        graph: rocket_like::rocket_like(1, 0.01),
                        stimulus: Stimulus::Random(10),
                        default_cycles: 2_000,
                        lane_init: vec![],
                    });
                }
                let cores: usize = rest.strip_suffix('c')?.parse().ok()?;
                return Some(Design {
                    name: name.into(),
                    graph: rocket_like::rocket_like(cores, 0.1),
                    stimulus: Stimulus::Random(11),
                    // paper Table 3: rocket runs 540K cycles; scaled 1/100
                    default_cycles: 5_400,
                    lane_init: vec![],
                });
            }
            if let Some(rest) = name.strip_prefix("boom_like_") {
                let cores: usize = rest.strip_suffix('c')?.parse().ok()?;
                return Some(Design {
                    name: name.into(),
                    graph: boom_like::boom_like(cores, 0.1),
                    stimulus: Stimulus::Random(12),
                    default_cycles: 7_500,
                    lane_init: vec![],
                });
            }
            if let Some(rest) = name.strip_prefix("gemmini_like_") {
                let dim: usize = rest.parse().ok()?;
                return Some(Design {
                    name: name.into(),
                    graph: gemmini_like::gemmini_like(dim),
                    stimulus: Stimulus::Random(13),
                    default_cycles: 16_000,
                    lane_init: vec![],
                });
            }
            if let Some(rest) = name.strip_prefix("alu_farm_") {
                let blocks: usize = rest.parse().ok()?;
                if blocks == 0 {
                    return None;
                }
                return Some(Design {
                    name: name.into(),
                    graph: simple::alu_farm(blocks, 32),
                    stimulus: Stimulus::Random(14),
                    default_cycles: 10_000,
                    lane_init: vec![],
                });
            }
            return None;
        }
    };
    Some(d)
}

/// The canonical single-module edit used by the incremental-compile
/// benchmarks: XOR one stage register's next-state value with a fixed
/// constant. Targets `c0_s0` (rocket_like), `b0_rob0` (boom_like), or
/// the first register otherwise; panics on register-free designs.
pub fn apply_module_edit(g: &mut Graph) {
    use crate::graph::ops::{mask, PrimOp};
    assert!(!g.regs.is_empty(), "cannot apply a module edit to a register-free design");
    let idx = g.regs.iter().position(|r| r.name == "c0_s0" || r.name == "b0_rob0").unwrap_or(0);
    let (reg_node, old_next, w) = (g.regs[idx].node, g.regs[idx].next, g.regs[idx].width);
    let k = g.konst(0x5A5A_5A5A & mask(w), w);
    let x = g.prim_w(PrimOp::Xor, &[old_next, k], w);
    g.connect_reg(reg_node, x);
}

/// Names used by the main evaluation (paper Fig 20's x-axis analog).
pub fn main_eval_designs() -> Vec<&'static str> {
    vec![
        "rocket_like_1c",
        "rocket_like_4c",
        "rocket_like_8c",
        "boom_like_1c",
        "boom_like_4c",
        "boom_like_8c",
        "gemmini_like_8",
        "gemmini_like_16",
        "keccak",
        "tiny_cpu",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_designs_are_valid() {
        for name in ["counter", "alu32", "fir8", "rocket_like_1c", "boom_like_1c", "gemmini_like_4"] {
            let d = catalog(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(d.graph.validate().is_empty(), "{name}: {:?}", d.graph.validate());
            assert!(d.graph.num_ops() > 0);
        }
        assert!(catalog("nonexistent").is_none());
    }

    #[test]
    fn lane_stimulus_is_consistent_and_decorrelated() {
        let d = catalog("alu32").unwrap();
        let lanes = 4usize;
        let n = d.graph.inputs.len();
        let mut batched = d.make_lane_stimulus(lanes);
        let mut singles: Vec<_> = (0..lanes).map(|l| d.make_stimulus_for_lane(l)).collect();
        let mut base = d.make_stimulus();
        let mut lanes_differ = false;
        for cycle in 0..16u64 {
            let flat = batched(cycle);
            assert_eq!(flat.len(), n * lanes);
            let b = base(cycle);
            for (l, s) in singles.iter_mut().enumerate() {
                let want = s(cycle);
                for i in 0..n {
                    assert_eq!(flat[i * lanes + l], want[i], "lane {l} port {i}");
                }
                if l == 0 {
                    assert_eq!(want, b, "lane 0 must replay the base stimulus");
                } else if want != b {
                    lanes_differ = true;
                }
            }
        }
        assert!(lanes_differ, "lanes 1.. must be decorrelated from lane 0");
    }

    /// Toggle-stimulus semantics: rate 0.0 freezes every lane after
    /// cycle 0; rate 1.0 changes every port of every lane every cycle.
    #[test]
    fn toggle_stimulus_rate_extremes() {
        let d = catalog("alu32").unwrap();
        let lanes = 3usize;
        let n = d.graph.inputs.len();

        let mut frozen = d.make_lane_stimulus_toggle(lanes, 0.0);
        let first = frozen(0);
        assert_eq!(first.len(), n * lanes);
        for cycle in 1..8u64 {
            assert_eq!(frozen(cycle), first, "rate 0.0 must hold after cycle 0");
        }

        let mut hot = d.make_lane_stimulus_toggle(lanes, 1.0);
        let mut prev = hot(0);
        for cycle in 1..8u64 {
            let cur = hot(cycle);
            for i in 0..n {
                for l in 0..lanes {
                    assert_ne!(
                        cur[i * lanes + l],
                        prev[i * lanes + l],
                        "rate 1.0 must change port {i} lane {l} at cycle {cycle}"
                    );
                }
            }
            prev = cur;
        }
    }

    #[test]
    fn rocket_scales_with_cores() {
        let one = catalog("rocket_like_1c").unwrap().graph.num_ops();
        let four = catalog("rocket_like_4c").unwrap().graph.num_ops();
        let ratio = four as f64 / one as f64;
        assert!((3.0..5.0).contains(&ratio), "ratio {ratio}");
    }
}
