//! SmallBOOM-like synthetic generator: a wider out-of-order-shaped core —
//! bigger mux ladders (issue select), more parallel ALU cones (more
//! functional units), a larger regfile, and wider layers than
//! `rocket_like`. ≈94 K effectual ops per core at `scale = 1.0`
//! (paper Table 1, Small-1c).

use crate::graph::builder::adapt_width;
use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeId};
use crate::util::prng::Rng;

use super::synth;

pub fn boom_like(cores: usize, scale: f64) -> Graph {
    let mut g = Graph::new(&format!("boom_like_{cores}c"));
    let mut rng = Rng::new(0xB004 + cores as u64);
    let io_in = g.input("io_in", 32);
    let flush = g.input("flush", 1);

    let mut bus: Vec<NodeId> = vec![io_in];
    let blocks = ((94_000.0 * scale) / 55.0).max(1.0) as usize;
    for core in 0..cores {
        let out = build_boom_core(&mut g, &mut rng, core, blocks, &bus, flush);
        bus.push(out);
    }
    let mut acc = adapt_width(&mut g, bus[0], 32);
    for &b in &bus[1..] {
        let bb = adapt_width(&mut g, b, 32);
        acc = g.prim(PrimOp::Xor, &[acc, bb]);
    }
    let r = g.reg("rob_head", 32, 0);
    g.connect_reg(r, acc);
    g.output("rob_head", r);
    g
}

fn build_boom_core(
    g: &mut Graph,
    rng: &mut Rng,
    core: usize,
    blocks: usize,
    bus: &[NodeId],
    flush: NodeId,
) -> NodeId {
    let mut pool: Vec<NodeId> = bus.to_vec();
    // physical regfile: 32 entries (wider than rocket's 16)
    let wen = bit(g, rng, &pool);
    let waddr = bits(g, rng, &pool, 5);
    let wdata = bits(g, rng, &pool, 32);
    let prf = synth::reg_bank(g, &format!("b{core}_prf"), 32, 32, wen, waddr, wdata);
    let raddr = bits(g, rng, &pool, 5);
    let rs = synth::bank_read(g, &prf, raddr);
    pool.push(rs);

    let mut last = rs;
    for blk in 0..blocks {
        // issue-select: *wide* mux ladder (12 deep — OoO select logic)
        let sels: Vec<NodeId> = (0..12).map(|_| bit(g, rng, &pool)).collect();
        let vals: Vec<NodeId> = (0..13).map(|_| *rng.pick(&pool)).collect();
        let issued = synth::mux_ladder(g, rng, &sels, &vals, 32);
        pool.push(issued);

        // 2 parallel functional units
        for _ in 0..2 {
            let a = *rng.pick(&pool);
            let outs = synth::alu_cone(g, rng, a, issued, 32);
            pool.extend_from_slice(&outs);
        }
        // rename/bypass plumbing
        let p = synth::plumbing(g, rng, issued);
        pool.extend_from_slice(&p);
        let p2 = synth::plumbing(g, rng, last);
        pool.extend_from_slice(&p2);

        // ROB-entry-ish register with flush
        let rob = g.reg(&format!("b{core}_rob{blk}"), 32, 0);
        let val = adapt_width(g, *rng.pick(&pool), 32);
        let zero = g.konst(0, 32);
        let nxt = g.prim(PrimOp::Mux, &[flush, zero, val]);
        g.connect_reg(rob, nxt);
        pool.push(rob);
        last = rob;
    }
    let a = adapt_width(g, last, 32);
    let b = adapt_width(g, rs, 32);
    g.prim(PrimOp::Or, &[a, b])
}

fn bit(g: &mut Graph, rng: &mut Rng, pool: &[NodeId]) -> NodeId {
    let src = *rng.pick(pool);
    if g.width(src) == 1 {
        src
    } else {
        let i = rng.index(g.width(src) as usize) as u8;
        g.prim(PrimOp::Bits(i, i), &[src])
    }
}

fn bits(g: &mut Graph, rng: &mut Rng, pool: &[NodeId], w: u8) -> NodeId {
    let src = *rng.pick(pool);
    adapt_width(g, src, w)
}

#[cfg(test)]
mod tests {
    #[test]
    fn boom_is_bigger_than_rocket() {
        let b = super::boom_like(1, 0.1);
        let r = super::super::rocket_like::rocket_like(1, 0.1);
        assert!(b.num_ops() > r.num_ops());
        assert!(b.validate().is_empty());
    }
}
