//! Shared building blocks for the synthetic Chipyard-like generators.
//!
//! The generators aim to reproduce the graph *statistics* the paper's
//! phenomena depend on: op mix dominated by mux ladders and bit-select
//! plumbing, moderate arithmetic, wide layers with long dependence chains,
//! and an identity-op ratio of ~5–10× (Table 1). Everything is driven by
//! a seeded PRNG, so a given (design, cores, scale) is reproducible.

use crate::graph::builder::adapt_width;
use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeId};
use crate::util::prng::Rng;

/// A pipeline-stage-like cluster: registers feeding a cone of logic.
pub struct Cluster {
    pub regs: Vec<NodeId>,
    pub outputs: Vec<NodeId>,
}

/// Build a mux ladder (decode/forwarding logic — the dominant structure).
pub fn mux_ladder(g: &mut Graph, _rng: &mut Rng, sels: &[NodeId], vals: &[NodeId], width: u8) -> NodeId {
    debug_assert!(!vals.is_empty());
    let mut cur = adapt_width(g, *vals.last().unwrap(), width);
    let n = sels.len().min(vals.len() - 1);
    for i in (0..n).rev() {
        let v = adapt_width(g, vals[i], width);
        cur = g.prim_w(PrimOp::Mux, &[sels[i], v, cur], width);
    }
    cur
}

/// An ALU-ish arithmetic cone over two operands.
pub fn alu_cone(g: &mut Graph, rng: &mut Rng, a: NodeId, b: NodeId, width: u8) -> Vec<NodeId> {
    let b = adapt_width(g, b, width);
    let a = adapt_width(g, a, width);
    let mut outs = Vec::new();
    outs.push(g.prim_w(PrimOp::Add, &[a, b], width));
    outs.push(g.prim_w(PrimOp::Sub, &[a, b], width));
    outs.push(g.prim(PrimOp::Xor, &[a, b]));
    outs.push(g.prim(PrimOp::And, &[a, b]));
    if rng.chance(0.5) {
        outs.push(g.prim(PrimOp::Or, &[a, b]));
    }
    if rng.chance(0.3) && width <= 32 {
        outs.push(g.prim_w(PrimOp::Mul, &[a, b], width));
    }
    outs.push(g.prim(PrimOp::Eq, &[a, b]));
    outs.push(g.prim(PrimOp::Lt, &[a, b]));
    outs
}

/// Bit-plumbing cone: extracts/concats (abundant in lowered FIRRTL).
pub fn plumbing(g: &mut Graph, rng: &mut Rng, src: NodeId) -> Vec<NodeId> {
    let w = g.width(src);
    let mut outs = Vec::new();
    let mid = (w / 2).max(1);
    outs.push(g.prim(PrimOp::Bits(w - 1, w - mid), &[src]));
    outs.push(g.prim(PrimOp::Bits(mid - 1, 0), &[src]));
    let x = outs[rng.index(outs.len())];
    let y = outs[rng.index(outs.len())];
    if g.width(x) as usize + g.width(y) as usize <= 64 {
        outs.push(g.prim(PrimOp::Cat, &[x, y]));
    }
    outs.push(g.prim(PrimOp::Orr, &[src]));
    outs
}

/// A register bank with decoded writes (regfile/RAM-ish structure):
/// `bank[i]' = (wen && waddr == i) ? wdata : bank[i]`.
pub fn reg_bank(
    g: &mut Graph,
    name: &str,
    n: usize,
    width: u8,
    wen: NodeId,
    waddr: NodeId,
    wdata: NodeId,
) -> Vec<NodeId> {
    let wdata = adapt_width(g, wdata, width);
    let mut regs = Vec::with_capacity(n);
    for i in 0..n {
        regs.push(g.reg(&format!("{name}_{i}"), width, 0));
    }
    for (i, &r) in regs.iter().enumerate() {
        let idx = g.konst(i as u64, g.width(waddr));
        let hit = g.prim(PrimOp::Eq, &[waddr, idx]);
        let sel = g.prim(PrimOp::And, &[wen, hit]);
        let nxt = g.prim_w(PrimOp::Mux, &[sel, wdata, r], width);
        g.connect_reg(r, nxt);
    }
    regs
}

/// Read port over a bank: a binary mux tree indexed by `addr`.
/// The bank is padded to a power of two by repeating the last entry.
pub fn bank_read(g: &mut Graph, bank: &[NodeId], addr: NodeId) -> NodeId {
    debug_assert!(!bank.is_empty());
    let n = bank.len().next_power_of_two();
    let mut padded: Vec<NodeId> = bank.to_vec();
    while padded.len() < n {
        padded.push(*bank.last().unwrap());
    }
    read_tree(g, &padded, addr, n.trailing_zeros() as u8)
}

fn read_tree(g: &mut Graph, slice: &[NodeId], addr: NodeId, bits_left: u8) -> NodeId {
    if slice.len() == 1 {
        return slice[0];
    }
    let half = slice.len() / 2;
    let sel_bit = bits_left - 1;
    let lo = read_tree(g, &slice[..half], addr, sel_bit);
    let hi = read_tree(g, &slice[half..], addr, sel_bit);
    let aw = g.width(addr);
    let b = if sel_bit < aw {
        g.prim(PrimOp::Bits(sel_bit, sel_bit), &[addr])
    } else {
        g.konst(0, 1)
    };
    g.prim(PrimOp::Mux, &[b, hi, lo])
}

/// Wire a cluster's next-state from a pool of candidate values.
pub fn connect_cluster(g: &mut Graph, rng: &mut Rng, regs: &[NodeId], pool: &[NodeId]) {
    for &r in regs {
        let src = pool[rng.index(pool.len())];
        let w = g.width(r);
        let adapted = adapt_width(g, src, w);
        g.connect_reg(r, adapted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RefSim;

    #[test]
    fn reg_bank_decoded_write_and_read() {
        let mut g = Graph::new("bank");
        let wen = g.input("wen", 1);
        let waddr = g.input("waddr", 3);
        let wdata = g.input("wdata", 8);
        let raddr = g.input("raddr", 3);
        let bank = reg_bank(&mut g, "m", 8, 8, wen, waddr, wdata);
        let rd = bank_read(&mut g, &bank, raddr);
        g.output("rd", rd);
        let mut sim = RefSim::new(g);
        // write 0xAB to address 5
        sim.step(&[1, 5, 0xAB, 0]);
        // read it back
        sim.step(&[0, 0, 0, 5]);
        assert_eq!(sim.outputs()[0].1, 0xAB);
        // unwritten address stays 0
        sim.step(&[0, 0, 0, 3]);
        assert_eq!(sim.outputs()[0].1, 0);
        // write to 2, read 5 still 0xAB
        sim.step(&[1, 2, 0x7F, 0]);
        sim.step(&[0, 0, 0, 5]);
        assert_eq!(sim.outputs()[0].1, 0xAB);
        sim.step(&[0, 0, 0, 2]);
        assert_eq!(sim.outputs()[0].1, 0x7F);
    }
}
