//! `tiny_cpu` — a real 32-bit RISC-style CPU built as a dataflow graph,
//! executing a real program to completion. This is the end-to-end
//! workload standing in for the paper's dhrystone runs: instruction ROM
//! (mux tree), 16-entry register file, 32-word RAM with decoded writes,
//! ALU, branch unit, and a DMI-style host window (paper §6.2 Host–DUT
//! communication) for peeking RAM.
//!
//! ISA (word-encoded, `[31:28] op | [27:24] rd | [23:20] rs1 |
//! [19:16] rs2 | [15:0] imm`):
//!
//! | op | mnemonic | semantics |
//! |----|----------|-----------|
//! | 0  | ADD  | rd = rs1 + rs2 |
//! | 1  | SUB  | rd = rs1 - rs2 |
//! | 2  | AND  | rd = rs1 & rs2 |
//! | 3  | OR   | rd = rs1 \| rs2 |
//! | 4  | XOR  | rd = rs1 ^ rs2 |
//! | 5  | SHL  | rd = rs1 << (rs2 & 31) |
//! | 6  | SHR  | rd = rs1 >> (rs2 & 31) |
//! | 7  | ADDI | rd = rs1 + imm (imm zero-extended) |
//! | 8  | LW   | rd = RAM[(rs1 + imm) & 31] |
//! | 9  | SW   | RAM[(rs1 + imm) & 31] = rs2 |
//! | 10 | BEQ  | if rs1 == rs2 { pc = imm } |
//! | 11 | BNE  | if rs1 != rs2 { pc = imm } |
//! | 12 | JMP  | pc = imm |
//! | 13 | HALT | stop (pc freezes, `halted` output raises) |
//!
//! `r0` is hard-wired to zero.

use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeId};

use super::synth::bank_read;

pub const RAM_WORDS: usize = 32;
pub const NUM_REGS: usize = 16;

// ---- assembler ----

pub fn enc(op: u32, rd: u32, rs1: u32, rs2: u32, imm: u32) -> u32 {
    (op << 28) | (rd << 24) | (rs1 << 20) | (rs2 << 16) | (imm & 0xFFFF)
}
pub fn add(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(0, rd, rs1, rs2, 0)
}
pub fn sub(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(1, rd, rs1, rs2, 0)
}
pub fn and(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(2, rd, rs1, rs2, 0)
}
pub fn or(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(3, rd, rs1, rs2, 0)
}
pub fn xor(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(4, rd, rs1, rs2, 0)
}
pub fn shl(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(5, rd, rs1, rs2, 0)
}
pub fn shr(rd: u32, rs1: u32, rs2: u32) -> u32 {
    enc(6, rd, rs1, rs2, 0)
}
pub fn addi(rd: u32, rs1: u32, imm: u32) -> u32 {
    enc(7, rd, rs1, 0, imm)
}
pub fn lw(rd: u32, rs1: u32, imm: u32) -> u32 {
    enc(8, rd, rs1, 0, imm)
}
pub fn sw(rs2: u32, rs1: u32, imm: u32) -> u32 {
    enc(9, 0, rs1, rs2, imm)
}
pub fn beq(rs1: u32, rs2: u32, target: u32) -> u32 {
    enc(10, 0, rs1, rs2, target)
}
pub fn bne(rs1: u32, rs2: u32, target: u32) -> u32 {
    enc(11, 0, rs1, rs2, target)
}
pub fn jmp(target: u32) -> u32 {
    enc(12, 0, 0, 0, target)
}
pub fn halt() -> u32 {
    enc(13, 0, 0, 0, 0)
}

/// The dhrystone-like benchmark program: a loop mixing ALU ops, loads,
/// stores and branches, accumulating a checksum into RAM[0].
pub fn dhrystone_like(iters: u32) -> Vec<u32> {
    vec![
        addi(1, 0, iters),  // 0: r1 = iters
        addi(2, 0, 0),      // 1: r2 = checksum = 0
        addi(3, 0, 12345),  // 2: r3 = seed
        addi(6, 0, 1),      // 3: r6 = 1
        addi(7, 0, 5),      // 4: r7 = 5 (shift amount)
        // loop:
        add(2, 2, 3),       // 5: checksum += seed
        xor(3, 3, 2),       // 6: seed ^= checksum
        shl(4, 3, 6),       // 7: r4 = seed << 1
        shr(5, 4, 7),       // 8: r5 = r4 >> 5
        or(3, 3, 5),        // 9: seed |= r5
        sw(2, 0, 1),        // 10: RAM[1] = checksum
        lw(8, 0, 1),        // 11: r8 = RAM[1]
        add(2, 2, 8),       // 12: checksum += r8 (doubles it)
        and(9, 2, 3),       // 13: r9 = checksum & seed
        sub(2, 2, 9),       // 14: checksum -= r9
        sub(1, 1, 6),       // 15: r1 -= 1
        bne(1, 0, 5),       // 16: loop while r1 != 0
        sw(2, 0, 0),        // 17: RAM[0] = checksum
        halt(),             // 18
    ]
}

/// Software golden model: returns (final checksum, executed instructions).
pub fn golden_run(program: &[u32], max_steps: usize) -> (u32, usize) {
    let mut regs = [0u32; NUM_REGS];
    let mut ram = [0u32; RAM_WORDS];
    let mut pc = 0usize;
    let mut steps = 0usize;
    while steps < max_steps {
        let inst = if pc < program.len() { program[pc] } else { halt() };
        let (op, rd, rs1, rs2, imm) = (
            inst >> 28,
            (inst >> 24) & 0xF,
            (inst >> 20) & 0xF,
            (inst >> 16) & 0xF,
            inst & 0xFFFF,
        );
        let a = regs[rs1 as usize];
        let b = regs[rs2 as usize];
        let mut next_pc = pc + 1;
        let mut wval = None;
        match op {
            0 => wval = Some(a.wrapping_add(b)),
            1 => wval = Some(a.wrapping_sub(b)),
            2 => wval = Some(a & b),
            3 => wval = Some(a | b),
            4 => wval = Some(a ^ b),
            5 => wval = Some(a << (b & 31)),
            6 => wval = Some(a >> (b & 31)),
            7 => wval = Some(a.wrapping_add(imm)),
            8 => wval = Some(ram[(a.wrapping_add(imm) & 31) as usize]),
            9 => ram[(a.wrapping_add(imm) & 31) as usize] = b,
            10 => {
                if a == b {
                    next_pc = imm as usize;
                }
            }
            11 => {
                if a != b {
                    next_pc = imm as usize;
                }
            }
            12 => next_pc = imm as usize,
            _ => return (ram[0], steps),
        }
        if let Some(v) = wval {
            if rd != 0 {
                regs[rd as usize] = v;
            }
        }
        pc = next_pc;
        steps += 1;
    }
    (ram[0], steps)
}

/// Build the CPU with `program` baked into the instruction ROM.
///
/// Inputs: `dmi_wen`, `dmi_addr[5]`, `dmi_wdata[32]` (host writes into
/// RAM — takes priority over CPU stores), and `dmi_raddr[5]`.
/// Outputs: `halted`, `checksum` (= RAM[0]), `pc`, `dmi_rdata`.
pub fn tiny_cpu(program: &[u32]) -> Graph {
    build_cpu(program, None)
}

/// Build the CPU with a *divergent-lane* instruction ROM: `rom_words`
/// self-holding registers named `rom{i}` (next state = themselves), each
/// initialized from `default_program` (padded with HALT). Because the ROM
/// words are architectural state rather than constants, they survive the
/// optimizer with stable names and can be re-initialized **per lane**
/// through [`crate::designs::Design::lane_init`] /
/// [`lane_rom_init`] — each lane of a batched run then executes a
/// different program over one shared OIM walk.
pub fn tiny_cpu_divergent(rom_words: usize, default_program: &[u32]) -> Graph {
    build_cpu(default_program, Some(rom_words))
}

/// The `Design::lane_init` entries loading one program per lane into a
/// [`tiny_cpu_divergent`] ROM (lane `l` runs `programs[l % programs.len()]`).
/// `rom_words` must match the value passed to `tiny_cpu_divergent`.
pub fn lane_rom_init(rom_words: usize, programs: &[Vec<u32>]) -> Vec<(String, Vec<u64>)> {
    let n = rom_words.next_power_of_two();
    assert!(!programs.is_empty());
    for p in programs {
        assert!(p.len() <= n, "program ({} words) exceeds ROM ({n} words)", p.len());
    }
    (0..n)
        .map(|i| {
            (
                format!("rom{i}"),
                programs
                    .iter()
                    .map(|p| p.get(i).copied().unwrap_or_else(halt) as u64)
                    .collect(),
            )
        })
        .collect()
}

fn build_cpu(program: &[u32], reg_rom_words: Option<usize>) -> Graph {
    assert!(program.len() <= 256, "ROM limit");
    let mut g = Graph::new("tiny_cpu");
    let dmi_wen = g.input("dmi_wen", 1);
    let dmi_addr = g.input("dmi_addr", 5);
    let dmi_wdata = g.input("dmi_wdata", 32);
    let dmi_raddr = g.input("dmi_raddr", 5);

    let halted = g.reg("halted", 1, 0);
    let pc = g.reg("pc", 8, 0);

    // ---- architectural registers (r0 = constant zero) ----
    let zero32 = g.konst(0, 32);
    let mut regs: Vec<NodeId> = vec![zero32];
    for i in 1..NUM_REGS {
        regs.push(g.reg(&format!("x{i}"), 32, 0));
    }

    // ---- instruction ROM: mux tree over pc ----
    let rom: Vec<NodeId> = match reg_rom_words {
        // constant ROM: words baked into the OIM as initial slot values
        None => program.iter().map(|&w| g.konst(w as u64, 32)).collect(),
        // divergent-lane ROM: self-holding registers (next = self, the
        // default wiring of `Graph::reg`), re-initializable per lane
        Some(words) => {
            let n = words.next_power_of_two();
            assert!(program.len() <= n, "program exceeds ROM ({n} words)");
            (0..n)
                .map(|i| {
                    let w = program.get(i).copied().unwrap_or_else(halt);
                    g.reg(&format!("rom{i}"), 32, w as u64)
                })
                .collect()
        }
    };
    let pc_idx_w = (64 - (rom.len().next_power_of_two() as u64 - 1).leading_zeros()).max(1) as u8;
    let pc_idx = g.prim(PrimOp::Bits(pc_idx_w.min(8) - 1, 0), &[pc]);
    let inst = bank_read(&mut g, &rom, pc_idx);

    // ---- decode ----
    let op = g.prim(PrimOp::Bits(31, 28), &[inst]);
    let rd = g.prim(PrimOp::Bits(27, 24), &[inst]);
    let rs1 = g.prim(PrimOp::Bits(23, 20), &[inst]);
    let rs2 = g.prim(PrimOp::Bits(19, 16), &[inst]);
    let imm = g.prim(PrimOp::Bits(15, 0), &[inst]);
    let imm32 = g.prim_w(PrimOp::Pad(32), &[imm], 32);

    // ---- register reads ----
    let a = bank_read(&mut g, &regs, rs1);
    let b = bank_read(&mut g, &regs, rs2);

    // ---- ALU ----
    let shamt = g.prim(PrimOp::Bits(4, 0), &[b]);
    let alu_add = g.prim_w(PrimOp::Add, &[a, b], 32);
    let alu_sub = g.prim_w(PrimOp::Sub, &[a, b], 32);
    let alu_and = g.prim(PrimOp::And, &[a, b]);
    let alu_or = g.prim(PrimOp::Or, &[a, b]);
    let alu_xor = g.prim(PrimOp::Xor, &[a, b]);
    let alu_shl = g.prim_w(PrimOp::Dshl, &[a, shamt], 32);
    let alu_shr = g.prim(PrimOp::Dshr, &[a, shamt]);
    let alu_addi = g.prim_w(PrimOp::Add, &[a, imm32], 32);

    // ---- memory ----
    let addr_full = g.prim_w(PrimOp::Add, &[a, imm32], 32);
    let mem_addr = g.prim(PrimOp::Bits(4, 0), &[addr_full]);
    let op_k = |g: &mut Graph, v: u64| g.konst(v, 4);
    let k_sw = op_k(&mut g, 9);
    let is_sw = g.prim(PrimOp::Eq, &[op, k_sw]);
    let not_halted = g.prim(PrimOp::Not, &[halted]);
    let cpu_wen = g.prim(PrimOp::And, &[is_sw, not_halted]);
    // DMI has priority on the RAM write port
    let ram_wen = g.prim(PrimOp::Or, &[cpu_wen, dmi_wen]);
    let ram_waddr = g.prim(PrimOp::Mux, &[dmi_wen, dmi_addr, mem_addr]);
    let ram_wdata = g.prim(PrimOp::Mux, &[dmi_wen, dmi_wdata, b]);
    let ram = super::synth::reg_bank(&mut g, "ram", RAM_WORDS, 32, ram_wen, ram_waddr, ram_wdata);
    let mem_rdata = bank_read(&mut g, &ram, mem_addr);
    let dmi_rdata = bank_read(&mut g, &ram, dmi_raddr);

    // ---- writeback value select (op mux ladder) ----
    let candidates: [(u64, NodeId); 9] = [
        (0, alu_add),
        (1, alu_sub),
        (2, alu_and),
        (3, alu_or),
        (4, alu_xor),
        (5, alu_shl),
        (6, alu_shr),
        (7, alu_addi),
        (8, mem_rdata),
    ];
    let mut wval = zero32;
    for &(code, val) in candidates.iter().rev() {
        let k = op_k(&mut g, code);
        let hit = g.prim(PrimOp::Eq, &[op, k]);
        wval = g.prim_w(PrimOp::Mux, &[hit, val, wval], 32);
    }
    // write enable: op <= 8 and rd != 0 and not halted
    let k9 = op_k(&mut g, 9);
    let writes = g.prim(PrimOp::Lt, &[op, k9]);
    let zero4 = g.konst(0, 4);
    let rd_nz = g.prim(PrimOp::Neq, &[rd, zero4]);
    let wen0 = g.prim(PrimOp::And, &[writes, rd_nz]);
    let wen = g.prim(PrimOp::And, &[wen0, not_halted]);
    for (i, &r) in regs.iter().enumerate().skip(1) {
        let k = g.konst(i as u64, 4);
        let hit = g.prim(PrimOp::Eq, &[rd, k]);
        let sel = g.prim(PrimOp::And, &[wen, hit]);
        let nxt = g.prim_w(PrimOp::Mux, &[sel, wval, r], 32);
        g.connect_reg(r, nxt);
    }

    // ---- next pc ----
    let one8 = g.konst(1, 8);
    let pc_inc = g.prim_w(PrimOp::Add, &[pc, one8], 8);
    let imm8 = g.prim(PrimOp::Bits(7, 0), &[imm]);
    let eq_ab = g.prim(PrimOp::Eq, &[a, b]);
    let ne_ab = g.prim(PrimOp::Neq, &[a, b]);
    let k_beq = op_k(&mut g, 10);
    let k_bne = op_k(&mut g, 11);
    let k_jmp = op_k(&mut g, 12);
    let k_halt = op_k(&mut g, 13);
    let is_beq = g.prim(PrimOp::Eq, &[op, k_beq]);
    let is_bne = g.prim(PrimOp::Eq, &[op, k_bne]);
    let is_jmp = g.prim(PrimOp::Eq, &[op, k_jmp]);
    let is_halt = g.prim(PrimOp::Eq, &[op, k_halt]);
    let beq_t = g.prim(PrimOp::And, &[is_beq, eq_ab]);
    let bne_t = g.prim(PrimOp::And, &[is_bne, ne_ab]);
    let br = g.prim(PrimOp::Or, &[beq_t, bne_t]);
    let take = g.prim(PrimOp::Or, &[br, is_jmp]);
    let pc_br = g.prim(PrimOp::Mux, &[take, imm8, pc_inc]);
    let pc_next = g.prim(PrimOp::Mux, &[halted, pc, pc_br]);
    g.connect_reg(pc, pc_next);

    // halted latch
    let set_halt = g.prim(PrimOp::And, &[is_halt, not_halted]);
    let halted_next = g.prim(PrimOp::Or, &[halted, set_halt]);
    g.connect_reg(halted, halted_next);

    g.output("halted", halted);
    g.output("checksum", ram[0]);
    g.output("pc", pc);
    g.output("dmi_rdata", dmi_rdata);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RefSim;

    fn run_to_halt(sim: &mut RefSim, max: usize) -> (u64, usize) {
        for cycle in 0..max {
            sim.step(&[0, 0, 0, 0]);
            let outs: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
            if outs["halted"] == 1 {
                return (outs["checksum"], cycle + 1);
            }
        }
        panic!("did not halt in {max} cycles");
    }

    #[test]
    fn executes_dhrystone_like_to_golden_checksum() {
        let prog = dhrystone_like(10);
        let (golden, steps) = golden_run(&prog, 100_000);
        assert!(steps > 50, "program actually loops");
        let g = tiny_cpu(&prog);
        assert!(g.validate().is_empty());
        let mut sim = RefSim::new(g);
        let (checksum, cycles) = run_to_halt(&mut sim, 10_000);
        assert_eq!(checksum, golden as u64, "checksum mismatch");
        // single-cycle core: cycles ≈ instruction count + 1
        assert!((cycles as i64 - steps as i64).abs() <= 2, "cycles {cycles} vs steps {steps}");
    }

    #[test]
    fn branches_and_memory() {
        // store 5 to RAM[3], load it back, add 1, store to RAM[0], halt
        let prog = vec![
            addi(1, 0, 5),
            sw(1, 0, 3),
            lw(2, 0, 3),
            addi(2, 2, 1),
            sw(2, 0, 0),
            halt(),
        ];
        let g = tiny_cpu(&prog);
        let mut sim = RefSim::new(g);
        let (checksum, _) = run_to_halt(&mut sim, 100);
        assert_eq!(checksum, 6);
    }

    #[test]
    fn dmi_writes_and_reads_ram() {
        let prog = vec![jmp(0)]; // spin forever
        let g = tiny_cpu(&prog);
        let mut sim = RefSim::new(g);
        // host writes 0xDEAD to RAM[7] via DMI
        sim.step(&[1, 7, 0xDEAD, 7]);
        sim.step(&[0, 0, 0, 7]);
        let outs: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
        assert_eq!(outs["dmi_rdata"], 0xDEAD);
    }

    #[test]
    fn r0_is_hardwired_zero() {
        let prog = vec![addi(0, 0, 99), sw(0, 0, 0), halt()];
        let g = tiny_cpu(&prog);
        let mut sim = RefSim::new(g);
        let (checksum, _) = run_to_halt(&mut sim, 100);
        assert_eq!(checksum, 0); // write to r0 discarded
    }
}
