//! Gemmini-like systolic array: a real `dim × dim` weight-stationary MAC
//! grid. Activations stream west→east, partial sums north→south; weights
//! sit in per-PE registers loaded through a decoded write port. Highly
//! regular — the design class where dedup/instance-reuse optimizations
//! shine (paper Box 1), and a contrast to the irregular SoC generators.

use crate::graph::ops::PrimOp;
use crate::graph::Graph;

pub fn gemmini_like(dim: usize) -> Graph {
    let mut g = Graph::new(&format!("gemmini_like_{dim}"));
    let w = 16u8; // element width
    // inputs: one activation per row, weight-load port
    let acts: Vec<_> = (0..dim).map(|r| g.input(&format!("act{r}"), w)).collect();
    let wld_en = g.input("wld_en", 1);
    let wld_row = g.input("wld_row", 8);
    let wld_col = g.input("wld_col", 8);
    let wld_val = g.input("wld_val", w);

    // per-PE state: weight reg, activation pipe reg, psum pipe reg
    let mut weight = vec![vec![0u32; dim]; dim];
    let mut act_pipe = vec![vec![0u32; dim]; dim];
    let mut psum_pipe = vec![vec![0u32; dim]; dim];
    for r in 0..dim {
        for c in 0..dim {
            weight[r][c] = g.reg(&format!("w_{r}_{c}"), w, 0);
            act_pipe[r][c] = g.reg(&format!("a_{r}_{c}"), w, 0);
            psum_pipe[r][c] = g.reg(&format!("p_{r}_{c}"), w, 0);
        }
    }

    for r in 0..dim {
        for c in 0..dim {
            // weight load decode
            let rk = g.konst(r as u64, 8);
            let ck = g.konst(c as u64, 8);
            let hr = g.prim(PrimOp::Eq, &[wld_row, rk]);
            let hc = g.prim(PrimOp::Eq, &[wld_col, ck]);
            let hit = g.prim(PrimOp::And, &[hr, hc]);
            let sel = g.prim(PrimOp::And, &[wld_en, hit]);
            let wn = g.prim_w(PrimOp::Mux, &[sel, wld_val, weight[r][c]], w);
            g.connect_reg(weight[r][c], wn);

            // activation flows west -> east
            let a_in = if c == 0 { acts[r] } else { act_pipe[r][c - 1] };
            g.connect_reg(act_pipe[r][c], a_in);

            // MAC: psum flows north -> south
            let p_in = if r == 0 { g.konst(0, w) } else { psum_pipe[r - 1][c] };
            let prod = g.prim_w(PrimOp::Mul, &[a_in, weight[r][c]], w);
            let sum = g.prim_w(PrimOp::Add, &[p_in, prod], w);
            g.connect_reg(psum_pipe[r][c], sum);
        }
    }

    // outputs: bottom-row partial sums, xor-condensed plus first column
    for c in 0..dim.min(4) {
        g.output(&format!("psum{c}"), psum_pipe[dim - 1][c]);
    }
    let mut acc = psum_pipe[dim - 1][0];
    for c in 1..dim {
        acc = g.prim_w(PrimOp::Xor, &[acc, psum_pipe[dim - 1][c]], w);
    }
    g.output("psum_xor", acc);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RefSim;

    /// Load a 2x2 identity weight matrix and stream an activation: the
    /// array must behave as a pipelined matmul by identity.
    #[test]
    fn identity_weights_pass_activations() {
        let g = gemmini_like(2);
        let mut sim = RefSim::new(g);
        let zero = |sim: &mut RefSim, acts: [u64; 2]| {
            // inputs: act0, act1, wld_en, wld_row, wld_col, wld_val
            sim.step(&[acts[0], acts[1], 0, 0, 0, 0]);
        };
        // load W = I
        sim.step(&[0, 0, 1, 0, 0, 1]);
        sim.step(&[0, 0, 1, 1, 1, 1]);
        // inject activation [5, 7]: row 0 hits w00=1 -> product 5 enters
        // column 0's psum stream
        zero(&mut sim, [5, 7]);
        // one more cycle for the partial sum to flow south to the bottom row
        zero(&mut sim, [0, 0]);
        let outs: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
        assert_eq!(outs["psum0"], 5, "{outs:?}");
    }

    #[test]
    fn scales_quadratically() {
        let a = gemmini_like(4).num_ops();
        let b = gemmini_like(8).num_ops();
        let ratio = b as f64 / a as f64;
        assert!((3.0..5.0).contains(&ratio), "{ratio}");
    }
}
