//! Keccak-f[1600] round datapath — the SHA3 accelerator role (paper §7.1).
//!
//! A *real* design: 25 × 64-bit lane registers plus a round counter; each
//! cycle applies one full Keccak-f round (θ, ρ, π, χ, ι) in combinational
//! logic, with the round constant selected by a mux ladder over the
//! counter. After 24 cycles the state holds the true permutation — tested
//! against a pure-software Keccak-f below.

use crate::graph::ops::PrimOp;
use crate::graph::{Graph, NodeId};

const RC: [u64; 24] = [
    0x0000000000000001,
    0x0000000000008082,
    0x800000000000808a,
    0x8000000080008000,
    0x000000000000808b,
    0x0000000080000001,
    0x8000000080008081,
    0x8000000000008009,
    0x000000000000008a,
    0x0000000000000088,
    0x0000000080008009,
    0x000000008000000a,
    0x000000008000808b,
    0x800000000000008b,
    0x8000000000008089,
    0x8000000000008003,
    0x8000000000008002,
    0x8000000000000080,
    0x000000000000800a,
    0x800000008000000a,
    0x8000000080008081,
    0x8000000000008080,
    0x0000000080000001,
    0x8000000080008008,
];

const RHO: [[u32; 5]; 5] = [
    [0, 36, 3, 41, 18],
    [1, 44, 10, 45, 2],
    [62, 6, 43, 15, 61],
    [28, 55, 25, 21, 56],
    [27, 20, 39, 8, 14],
];

/// rotl64 as cat(bits(lo), bits(hi)) — rotations are free wiring in RTL.
fn rotl(g: &mut Graph, x: NodeId, r: u32) -> NodeId {
    let r = (r % 64) as u8;
    if r == 0 {
        return x;
    }
    let hi = g.prim(PrimOp::Bits(63 - r, 0), &[x]); // low part -> high
    let lo = g.prim(PrimOp::Bits(63, 64 - r), &[x]); // top r bits -> low
    g.prim(PrimOp::Cat, &[hi, lo])
}

/// Build the round datapath. Inputs: `ld` (load state from `in0..in4`,
/// column-wise xor-spread for a compact port count) and `go`.
pub fn keccak_round_datapath() -> Graph {
    let mut g = Graph::new("keccak");
    let ld = g.input("ld", 1);
    let go = g.input("go", 1);
    let seed: Vec<NodeId> = (0..5).map(|i| g.input(&format!("in{i}"), 64)).collect();

    // state lanes a[x][y], round counter
    let mut a = vec![vec![0u32; 5]; 5];
    for (x, row) in a.iter_mut().enumerate() {
        for (y, lane) in row.iter_mut().enumerate() {
            *lane = g.reg(&format!("lane_{x}_{y}"), 64, 0);
        }
    }
    let rc_reg = g.reg("round", 5, 0);

    // θ: c[x] = xor of column; d[x] = c[x-1] ^ rotl(c[x+1], 1)
    let mut c = Vec::with_capacity(5);
    for x in 0..5 {
        let mut acc = a[x][0];
        for y in 1..5 {
            acc = g.prim(PrimOp::Xor, &[acc, a[x][y]]);
        }
        c.push(acc);
    }
    let mut d = Vec::with_capacity(5);
    for x in 0..5 {
        let rot = rotl(&mut g, c[(x + 1) % 5], 1);
        d.push(g.prim(PrimOp::Xor, &[c[(x + 4) % 5], rot]));
    }
    let mut theta = vec![vec![0u32; 5]; 5];
    for x in 0..5 {
        for y in 0..5 {
            theta[x][y] = g.prim(PrimOp::Xor, &[a[x][y], d[x]]);
        }
    }

    // ρ + π: b[y][(2x+3y)%5] = rotl(theta[x][y], RHO[x][y])
    let mut b = vec![vec![0u32; 5]; 5];
    for x in 0..5 {
        for y in 0..5 {
            let rot = rotl(&mut g, theta[x][y], RHO[x][y]);
            b[y][(2 * x + 3 * y) % 5] = rot;
        }
    }

    // χ: a'[x][y] = b ^ (~b[x+1] & b[x+2])
    let mut chi = vec![vec![0u32; 5]; 5];
    for x in 0..5 {
        for y in 0..5 {
            let n = g.prim(PrimOp::Not, &[b[(x + 1) % 5][y]]);
            let an = g.prim(PrimOp::And, &[n, b[(x + 2) % 5][y]]);
            chi[x][y] = g.prim(PrimOp::Xor, &[b[x][y], an]);
        }
    }

    // ι: round constant mux ladder over the counter
    let mut rc_val: NodeId = g.konst(0, 64);
    for (i, &rc) in RC.iter().enumerate().rev() {
        let k = g.konst(i as u64, 5);
        let hit = g.prim(PrimOp::Eq, &[rc_reg, k]);
        let c = g.konst(rc, 64);
        rc_val = g.prim(PrimOp::Mux, &[hit, c, rc_val]);
    }
    chi[0][0] = g.prim(PrimOp::Xor, &[chi[0][0], rc_val]);

    // next state: ld ? seed : (go ? chi : hold)
    for x in 0..5 {
        for y in 0..5 {
            // seed pattern: lane(x,y) = rotl(in_x, y*7) ^ y — cheap spread
            let seeded = rotl(&mut g, seed[x], (y * 7) as u32);
            let yk = g.konst(y as u64, 64);
            let seeded = g.prim(PrimOp::Xor, &[seeded, yk]);
            let stepped = g.prim(PrimOp::Mux, &[go, chi[x][y], a[x][y]]);
            let nxt = g.prim(PrimOp::Mux, &[ld, seeded, stepped]);
            g.connect_reg(a[x][y], nxt);
        }
    }
    // round counter
    let one = g.konst(1, 5);
    let zero5 = g.konst(0, 5);
    let inc = g.prim_w(PrimOp::Add, &[rc_reg, one], 5);
    let stepped = g.prim(PrimOp::Mux, &[go, inc, rc_reg]);
    let rc_next = g.prim(PrimOp::Mux, &[ld, zero5, stepped]);
    g.connect_reg(rc_reg, rc_next);

    g.output("lane00", a[0][0]);
    g.output("lane12", a[1][2]);
    g.output("lane44", a[4][4]);
    g.output("round", rc_reg);
    g
}

/// Pure-software Keccak-f[1600] (golden model for the datapath test).
pub fn keccak_f_sw(state: &mut [[u64; 5]; 5]) {
    for rc in RC {
        // θ
        let mut c = [0u64; 5];
        for x in 0..5 {
            c[x] = state[x][0] ^ state[x][1] ^ state[x][2] ^ state[x][3] ^ state[x][4];
        }
        let mut d = [0u64; 5];
        for x in 0..5 {
            d[x] = c[(x + 4) % 5] ^ c[(x + 1) % 5].rotate_left(1);
        }
        for x in 0..5 {
            for y in 0..5 {
                state[x][y] ^= d[x];
            }
        }
        // ρ + π
        let mut b = [[0u64; 5]; 5];
        for x in 0..5 {
            for y in 0..5 {
                b[y][(2 * x + 3 * y) % 5] = state[x][y].rotate_left(RHO[x][y]);
            }
        }
        // χ
        for x in 0..5 {
            for y in 0..5 {
                state[x][y] = b[x][y] ^ (!b[(x + 1) % 5][y] & b[(x + 2) % 5][y]);
            }
        }
        // ι
        state[0][0] ^= rc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RefSim;

    #[test]
    fn datapath_matches_software_keccak() {
        let g = keccak_round_datapath();
        assert!(g.validate().is_empty());
        let mut sim = RefSim::new(g);
        let ins: [u64; 5] = [0x0123456789ABCDEF, 0xFEDCBA9876543210, 0xDEADBEEFCAFEF00D, 7, 42];
        // golden initial state mirrors the seed spread
        let mut golden = [[0u64; 5]; 5];
        for x in 0..5 {
            for y in 0..5 {
                golden[x][y] = ins[x].rotate_left((y * 7) as u32) ^ y as u64;
            }
        }
        keccak_f_sw(&mut golden);

        // hardware: load, then 24 rounds
        let mut inputs = vec![1u64, 0];
        inputs.extend_from_slice(&ins);
        sim.step(&inputs); // ld
        let mut go = vec![0u64, 1];
        go.extend_from_slice(&[0, 0, 0, 0, 0]);
        for _ in 0..24 {
            sim.step(&go);
        }
        let outs: std::collections::HashMap<String, u64> = sim.outputs().into_iter().collect();
        assert_eq!(outs["lane00"], golden[0][0], "lane00");
        assert_eq!(outs["lane12"], golden[1][2], "lane12");
        assert_eq!(outs["lane44"], golden[4][4], "lane44");
        assert_eq!(outs["round"], 24);
    }
}
