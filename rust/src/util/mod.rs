//! Offline utility substrates.
//!
//! The offline crate registry for this build lacks `serde_json`, `clap`,
//! `rand`, `proptest` and `criterion`; these small modules stand in for them
//! so the rest of the library has no external dependencies beyond `xla`.

pub mod fnv;
pub mod json;
pub mod prng;
pub mod cli;
pub mod tables;
pub mod alloc;
pub mod bench;
pub mod propcheck;

/// Format a byte count human-readably (e.g. `1.25 MB`).
pub fn fmt_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in adaptive units.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(std::time::Duration::from_secs(2)), "2.00 s");
        assert_eq!(fmt_duration(std::time::Duration::from_micros(1500)), "1.50 ms");
    }
}
