//! Minimal property-testing harness (no `proptest` offline).
//!
//! A property is a closure over a seeded [`crate::util::prng::Rng`]; the
//! harness runs it for N seeds and reports the first failing seed so a
//! failure is reproducible with `check_seed`. Shrinking is delegated to the
//! generators: they take a `size` parameter that the harness sweeps from
//! small to large, so the first failure tends to be near-minimal.

use crate::util::prng::Rng;

/// Number of cases per property (override with RTEAAL_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("RTEAAL_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(48)
}

/// Run `prop(rng, size)` for `cases` seeds with sizes ramping up.
/// Panics with the failing seed + size on the first failure.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng, usize) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // sizes ramp 1..=max so early failures are small
        let size = 1 + case * 24 / cases.max(1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {size}):\n{msg}\n\
                 reproduce with propcheck::check_seed(\"{name}\", {seed:#x}, {size}, prop)"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_seed(
    name: &str,
    seed: u64,
    size: usize,
    mut prop: impl FnMut(&mut Rng, usize) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng, size) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

/// Assert helper that produces a `Result<(), String>` for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Equality assertion with context.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (av, bv) = (&$a, &$b);
        if av != bv {
            return Err(format!("{} != {} ({})", stringify!($a), stringify!($b), format!($($fmt)+)));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64-roundtrip", 16, |rng, _size| {
            let x = rng.next_u64();
            prop_assert!(x.wrapping_add(1).wrapping_sub(1) == x, "wrap failed for {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 4, |_rng, _size| Err("nope".into()));
    }
}
