//! Micro-benchmark harness (no `criterion` offline).
//!
//! Provides warmup + repeated timed runs with median/mean/min reporting,
//! and a black-box sink to defeat dead-code elimination.

use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Statistics over a set of timed samples.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<Duration>) -> Self {
        assert!(!xs.is_empty());
        xs.sort();
        let total: Duration = xs.iter().sum();
        Stats {
            samples: xs.len(),
            min: xs[0],
            median: xs[xs.len() / 2],
            mean: total / xs.len() as u32,
            max: *xs.last().unwrap(),
        }
    }
}

/// Benchmark runner configuration. Defaults favour short total runtime:
/// experiments here are *shape* reproductions, not publication timings.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // RTEAAL_BENCH_SAMPLES / RTEAAL_BENCH_WARMUP override for longer runs.
        let samples = std::env::var("RTEAAL_BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let warmup = std::env::var("RTEAAL_BENCH_WARMUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1);
        Self { warmup, samples }
    }
}

impl Bencher {
    /// Time `f()` (which should perform one full measured workload).
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        Stats::from_samples(samples)
    }

    /// Time a single run (for expensive workloads like full compiles).
    pub fn once<T>(&self, f: impl FnOnce() -> T) -> (T, Duration) {
        let t0 = Instant::now();
        let r = f();
        (r, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.max, Duration::from_millis(3));
        assert_eq!(s.mean, Duration::from_millis(2));
    }

    #[test]
    fn run_counts() {
        let b = Bencher { warmup: 2, samples: 5 };
        let mut calls = 0;
        let s = b.run(|| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.samples, 5);
    }
}
