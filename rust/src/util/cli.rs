//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `command [--flag] [--key value] [positional...]` with typed
//! accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub flags: Vec<String>,
    pub opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the subcommand.
    pub fn parse(argv: &[String]) -> Self {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value`, `--key value`, or boolean `--flag`
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap().clone();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = tok.clone();
            } else {
                out.positional.push(tok.clone());
            }
        }
        out
    }

    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.opt_u64(name, default as u64)? as usize)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.opt(name).ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&v(&["sim", "--design", "rocket", "--cycles=100", "--vcd", "out.vcd", "pos1"]));
        assert_eq!(a.command, "sim");
        assert_eq!(a.opt("design"), Some("rocket"));
        assert_eq!(a.opt_u64("cycles", 0).unwrap(), 100);
        assert_eq!(a.opt("vcd"), Some("out.vcd"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&v(&["report", "--verbose", "--fast"]));
        assert!(a.flag("verbose"));
        assert!(a.flag("fast"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_errors() {
        let a = Args::parse(&v(&["x", "--n", "abc"]));
        assert!(a.opt_u64("n", 1).is_err());
        assert!(a.require("missing").is_err());
    }
}
