//! ASCII table rendering for experiment reports — the bench harness prints
//! the same rows the paper's tables/figures report.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len().max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                line.push_str(&format!("{:<w$}", cell, w = widths[i] + 2));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum::<usize>().saturating_sub(2)));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Emit as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV beside the bench run under `results/`.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xxxx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a     bbbb"));
        assert!(s.contains("xxxx  y"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "z".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c\n"));
        assert!(csv.contains("\"x\"\"y\",z"));
    }
}
