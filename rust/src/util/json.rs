//! Minimal JSON reader/writer (no `serde_json` offline).
//!
//! The paper stores the `OIM` tensors as JSON files loaded at runtime
//! (§6.1); this module provides the value model, a recursive-descent parser
//! and a compact writer. It supports the full JSON grammar except for
//! `\uXXXX` surrogate pairs outside the BMP (sufficient for our ASCII
//! schemas) and deliberately keeps numbers as `f64` plus a lossless `i64`
//! fast path for large integer arrays (tensor payloads).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Integer-valued numbers are held losslessly as `i64` where
/// possible (`Num` is used for the general case).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Schema(format!("missing field '{key}'")))
    }
    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.req(key)?.as_u64().ok_or_else(|| JsonError::Schema(format!("field '{key}' not a u64")))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?.as_usize().ok_or_else(|| JsonError::Schema(format!("field '{key}' not a usize")))
    }
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError::Schema(format!("field '{key}' not a string")))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?.as_arr().ok_or_else(|| JsonError::Schema(format!("field '{key}' not an array")))
    }
    /// Decode an array of u64s (tensor payload convenience).
    pub fn req_u64_vec(&self, key: &str) -> Result<Vec<u64>, JsonError> {
        self.req_arr(key)?
            .iter()
            .map(|v| v.as_u64().ok_or_else(|| JsonError::Schema(format!("'{key}' element not u64"))))
            .collect()
    }
    pub fn req_u32_vec(&self, key: &str) -> Result<Vec<u32>, JsonError> {
        Ok(self.req_u64_vec(key)?.into_iter().map(|v| v as u32).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr_u64(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Int(x as i64)).collect())
}
pub fn arr_u32(xs: &[u32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Int(x as i64)).collect())
}
pub fn arr_str(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
}

#[derive(Debug)]
pub enum JsonError {
    Parse(usize, String),
    Schema(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse(pos, msg) => write!(f, "json parse error at byte {pos}: {msg}"),
            JsonError::Schema(msg) => write!(f, "json schema error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(JsonError::Parse(p.pos, "trailing data".into()));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError::Parse(self.pos, msg.to_string()))
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err("invalid literal")
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError::Parse(self.pos, "bad \\u".into()))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError::Parse(self.pos, "bad hex".into()))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    if start + len > self.b.len() {
                        return self.err("truncated utf8");
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| JsonError::Parse(start, "bad utf8".into()))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Parse(start, "bad number".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[1,2,3],"c":{"d":null,"e":true},"f":"hi\n","g":-2.5}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.req_u64("a").unwrap(), 1);
        assert_eq!(v.req_arr("b").unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("e"), Some(&Json::Bool(true)));
    }

    #[test]
    fn large_int_arrays() {
        let xs: Vec<u64> = (0..1000).map(|i| i * 7919).collect();
        let j = obj(vec![("xs", arr_u64(&xs))]);
        let v = parse(&j.to_string()).unwrap();
        assert_eq!(v.req_u64_vec("xs").unwrap(), xs);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let v = parse(&j.to_string()).unwrap();
        assert_eq!(v, j);
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = Json::Str("λ→∀ fibertree".into());
        assert_eq!(parse(&j.to_string()).unwrap(), j);
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn floats_and_exponents() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
    }
}
