//! Deterministic PRNG (SplitMix64 + xoshiro256**), replacing the absent
//! `rand` crate. Used by stimulus generation, synthetic design generators
//! and the property-test harness. Deterministic by construction: every
//! experiment is reproducible from its seed.

/// SplitMix64: used for seeding and as a simple stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (statistical quality is not a requirement; determinism is).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Random f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Random value masked to `width` bits (1..=64).
    pub fn bits(&mut self, width: u8) -> u64 {
        let v = self.next_u64();
        if width >= 64 { v } else { v & ((1u64 << width) - 1) }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn bits_masked() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            assert!(r.bits(12) < (1 << 12));
        }
        // width 64 must not panic / truncate
        let _ = r.bits(64);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
