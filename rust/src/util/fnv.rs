//! The 128-bit content hash shared by the design cache and the
//! incremental compiler.
//!
//! Two independent FNV-1a streams concatenated to a 128-bit key. The
//! second stream perturbs both the offset basis and each input byte, so
//! the halves do not cancel; 128 bits puts accidental collisions between
//! distinct designs (and distinct register cones) out of practical
//! reach. Moved out of `service/cache.rs` so `graph::cone` can hash
//! per-register cones with byte-identical semantics.

const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
const FNV_BASIS: u64 = 0xcbf2_9ce4_8422_2325;

/// Single-stream 64-bit FNV-1a over a byte slice — the checksum variant
/// (checkpoint trailers). Same constants as [`Fnv2`]'s primary stream.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_BASIS;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Dual-stream FNV-1a accumulator (see module docs).
pub struct Fnv2 {
    a: u64,
    b: u64,
}

impl Fnv2 {
    pub fn new() -> Self {
        Fnv2 { a: FNV_BASIS, b: FNV_BASIS ^ 0x9e37_79b9_7f4a_7c15 }
    }

    #[inline]
    pub fn byte(&mut self, x: u8) {
        self.a = (self.a ^ x as u64).wrapping_mul(FNV_PRIME);
        self.b = (self.b ^ (x ^ 0x5a) as u64).wrapping_mul(FNV_PRIME);
    }

    pub fn word(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.byte(b);
        }
    }

    /// Length-prefixed, so `("ab","c")` and `("a","bc")` hash apart.
    pub fn text(&mut self, s: &str) {
        self.word(s.len() as u64);
        for b in s.bytes() {
            self.byte(b);
        }
    }

    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.a, self.b)
    }
}

impl Default for Fnv2 {
    fn default() -> Self {
        Fnv2::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_is_32_chars_and_input_sensitive() {
        let mut a = Fnv2::new();
        a.text("hello");
        let mut b = Fnv2::new();
        b.text("hello");
        assert_eq!(a.hex(), b.hex());
        assert_eq!(a.hex().len(), 32);
        let mut c = Fnv2::new();
        c.text("hellp");
        assert_ne!(a.hex(), c.hex());
    }

    #[test]
    fn length_prefix_separates_concatenations() {
        let mut a = Fnv2::new();
        a.text("ab");
        a.text("c");
        let mut b = Fnv2::new();
        b.text("a");
        b.text("bc");
        assert_ne!(a.hex(), b.hex());
    }
}
