//! Heap usage tracking — replaces /usr/bin/time-style peak-RSS measurement
//! for the paper's compile-memory experiments (Figs 8/15, Table 7b).
//!
//! A wrapping global allocator keeps live/peak byte counters; experiments
//! bracket a compile phase with [`reset_peak`]/[`peak_bytes`] to report peak
//! heap in that phase. Binaries and benches opt in with
//! `rteaal::util::alloc::install!();` at crate root.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

pub static LIVE: AtomicUsize = AtomicUsize::new(0);
pub static PEAK: AtomicUsize = AtomicUsize::new(0);

/// Tracking allocator; wraps the system allocator.
pub struct TrackingAlloc;

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                let live = LIVE.fetch_add(new_size - layout.size(), Ordering::Relaxed) + new_size
                    - layout.size();
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

/// Install the tracking allocator in a binary/bench crate.
#[macro_export]
macro_rules! install_tracking_alloc {
    () => {
        #[global_allocator]
        static GLOBAL_ALLOC: $crate::util::alloc::TrackingAlloc =
            $crate::util::alloc::TrackingAlloc;
    };
}

/// Current live heap bytes.
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Peak heap bytes since the last [`reset_peak`].
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

/// Reset the peak to the current live count (phase bracketing).
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Measure peak heap growth across `f`, returning `(result, peak_delta)`.
/// Only meaningful when the tracking allocator is installed; otherwise
/// returns 0 delta.
pub fn measure_peak<T>(f: impl FnOnce() -> T) -> (T, usize) {
    let before_live = live_bytes();
    reset_peak();
    let r = f();
    let delta = peak_bytes().saturating_sub(before_live);
    (r, delta)
}
