//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The offline crate registry for this build has no `anyhow`; this vendored
//! shim provides the subset the workspace uses: [`Error`] with a context
//! chain, [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, as anyhow does.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_render() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_bail() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing value")?;
            if v == 0 {
                bail!("zero is not allowed (got {v})");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{:#}", f(None).unwrap_err()), "missing value");
        assert!(format!("{:#}", f(Some(0)).unwrap_err()).contains("zero"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
