//! Offline stub of the `xla` crate (the xla_extension / PJRT bindings).
//!
//! The build environment has no crates.io access and no XLA shared library,
//! so this stub provides the exact API surface `rteaal::runtime` uses —
//! every constructor that would touch PJRT returns a runtime error instead.
//! Swapping a real `xla` crate into `Cargo.toml` restores the backend; no
//! call site changes are needed.

use std::fmt;

/// Error type matching the real crate's `Display`-able error.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA runtime not available: this binary was built against the offline stub \
         crate (vendor/xla); install xla_extension and swap the real `xla` crate in"
            .to_string(),
    ))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (stub).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
        let lit = Literal::vec1(&[1u32, 2, 3]);
        assert!(lit.reshape(&[3]).is_err());
    }
}
