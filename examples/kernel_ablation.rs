//! The paper's central ablation in miniature: sweep all seven kernel
//! configurations over one design and print the sim-time / program-size /
//! metadata-size trade-off (paper §7.2), plus each machine model's view.
//!
//! Run: `cargo run --release --example kernel_ablation [design]`

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::coordinator::sweep;
use rteaal::designs::catalog;
use rteaal::kernels::ALL_KERNELS;
use rteaal::perf::machine;
use rteaal::perf::trace::SimStyle;
use rteaal::util::fmt_bytes;
use rteaal::util::tables::Table;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "rocket_like_2c".into());
    let d = catalog(&name).expect("unknown design");
    let c = compile_design(&d, CompileOpts::default());
    let cycles = 2000;

    let mut t = Table::new(
        &format!("kernel ablation — {name} ({} ops, {} layers)", c.ir.total_ops(), c.ir.depth()),
        &["kernel", "Mcyc/s", "program", "metadata", "Xeon frontend", "Xeon IPC"],
    );
    let xeon = machine::intel_xeon();
    for cfg in ALL_KERNELS {
        let p = sweep::measure_kernel(&d, &c, cfg, cycles);
        let (_, td) = sweep::modeled(&c, SimStyle::Kernel(cfg), &xeon, 2);
        t.row(vec![
            cfg.name().to_string(),
            format!("{:.2}", p.hz / 1e6),
            fmt_bytes(p.program_bytes),
            fmt_bytes(p.data_bytes),
            format!("{:.1}%", td.frontend_bound * 100.0),
            format!("{:.2}", td.ipc),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
