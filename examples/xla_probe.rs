//! Diagnostic probe: run small HLO-text modules through the PJRT runtime
//! and print results (used to verify which HLO constructs round-trip to
//! xla_extension 0.5.1 — see DESIGN.md §Runtime).

use rteaal::runtime::pjrt::PjrtRuntime;

fn main() -> anyhow::Result<()> {
    let rt = PjrtRuntime::cpu()?;
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(|s| s == "backend").unwrap_or(false) {
        let dir = std::path::Path::new(&args[1]);
        let mut b = rteaal::runtime::XlaBackend::load(&rt, dir, &args[2])?;
        let nz = b.state.iter().filter(|&&v| v != 0).count();
        eprintln!("init state nonzero: {nz} / {}", b.state.len());
        for c in 0..b.chunk as u64 {
            b.step(&vec![0u64; b.num_inputs])?;
            let _ = c;
        }
        let nz = b.state.iter().filter(|&&v| v != 0).count();
        eprintln!("after 1 chunk nonzero: {nz}; outputs {:?}", b.outputs());
        let txt: String = b.state.iter().map(|v| format!("{v}\n")).collect();
        std::fs::write("/tmp/rust_state.txt", txt)?;
        return Ok(());
    }
    if args.first().map(|s| s == "tiny").unwrap_or(false) {
        // run a tiny_cpu-shaped module: state from tensors.json init, zero inputs
        let exe = rt.compile_hlo_file(std::path::Path::new(&args[1]))?;
        let j = rteaal::util::json::parse(&std::fs::read_to_string("artifacts/tiny_cpu.tensors.json")?)?;
        let mut state = vec![0u32; j.req_usize("num_slots")?];
        let slots = j.req_u64_vec("init_slots")?;
        let vals = j.req_u64_vec("init_vals")?;
        for (s, v) in slots.iter().zip(&vals) { state[*s as usize] = *v as u32; }
        let chunk: usize = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(4);
        let st = xla::Literal::vec1(&state);
        let xx = xla::Literal::vec1(&vec![0u32; chunk * 4]).reshape(&[chunk as i64, 4])?;
        let r = exe.execute::<xla::Literal>(&[st, xx])?[0][0].to_literal_sync()?;
        let (st2, outs) = r.to_tuple2()?;
        let sv = st2.to_vec::<u32>()?;
        let ov = outs.to_vec::<u32>()?;
        eprintln!("state nonzero: {}, last outputs row: {:?}", sv.iter().filter(|&&v| v != 0).count(), &ov[ov.len()-4..]);
        return Ok(());
    }
    for path in std::env::args().skip(1) {
        let exe = rt.compile_hlo_file(std::path::Path::new(&path))?;
        let st = xla::Literal::vec1(&(0..8u32).collect::<Vec<_>>());
        let xx = xla::Literal::vec1(&(0..8u32).map(|v| v + 10).collect::<Vec<_>>()).reshape(&[4, 2])?;
        let r = exe.execute::<xla::Literal>(&[st, xx])?[0][0].to_literal_sync()?;
        let parts = r.to_tuple()?;
        print!("{path}:");
        for p in &parts {
            print!(" {:?}", p.to_vec::<u32>()?);
        }
        println!();
    }
    Ok(())
}

#[allow(dead_code)]
fn unused() {}
