//! End-to-end driver across all three layers: the `tiny_cpu` design runs
//! its dhrystone-like program to completion on the **XLA/PJRT backend**
//! (L1 Pallas ALU inside the L2 jax cycle model, AOT-compiled, executed
//! from Rust), and the checksum is verified against the software golden
//! model and the native PSU kernel. Recorded in EXPERIMENTS.md §E2E.
//!
//! Requires `make artifacts`. Run: `cargo run --release --example tensor_e2e`

use std::time::Instant;

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::{catalog, tiny_cpu};
use rteaal::kernels::{build_with_oim, KernelConfig};
use rteaal::runtime::pjrt::PjrtRuntime;
use rteaal::runtime::XlaBackend;

fn main() -> anyhow::Result<()> {
    let prog = tiny_cpu::dhrystone_like(40);
    let (golden, instructions) = tiny_cpu::golden_run(&prog, 1_000_000);
    println!("golden model: checksum={golden:#010x} after {instructions} instructions");

    // --- native kernel run (L3 interpreter) ---
    let d = catalog("tiny_cpu").expect("design");
    let c = compile_design(&d, CompileOpts { fuse: false });
    let mut native = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);
    let t0 = Instant::now();
    let mut native_cycles = 0u64;
    loop {
        native.step(&[0, 0, 0, 0]);
        native_cycles += 1;
        if native.outputs().iter().any(|(n, v)| n == "halted" && *v == 1) {
            break;
        }
        assert!(native_cycles < 100_000, "did not halt");
    }
    let native_wall = t0.elapsed();
    let native_checksum =
        native.outputs().iter().find(|(n, _)| n == "checksum").map(|(_, v)| *v).unwrap();
    println!(
        "native PSU: halted after {native_cycles} cycles in {native_wall:?} \
         ({:.1} kcyc/s), checksum={native_checksum:#010x}",
        native_cycles as f64 / native_wall.as_secs_f64() / 1e3
    );
    assert_eq!(native_checksum, golden as u64, "native checksum mismatch");

    // --- XLA backend run (L2+L1 via PJRT) ---
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut xla = XlaBackend::load(&rt, std::path::Path::new("artifacts"), "tiny_cpu")?;
    let t0 = Instant::now();
    let mut xla_cycles = 0u64;
    let halted_idx =
        xla.output_names.iter().position(|n| n == "halted").expect("halted output");
    'outer: loop {
        for _ in 0..xla.chunk {
            xla.step(&[0, 0, 0, 0])?;
            xla_cycles += 1;
        }
        // inspect every cycle of the chunk for the halt edge
        let per = xla.num_outputs;
        for (row, chunk_row) in xla.chunk_outputs().chunks(per).enumerate() {
            if chunk_row[halted_idx] == 1 {
                xla_cycles = xla_cycles - xla.chunk as u64 + row as u64 + 1;
                break 'outer;
            }
        }
        assert!(xla_cycles < 100_000, "did not halt");
    }
    let xla_wall = t0.elapsed();
    let xla_checksum =
        xla.outputs().iter().find(|(n, _)| n == "checksum").map(|(_, v)| *v).unwrap();
    println!(
        "xla backend: halted by cycle {xla_cycles} in {xla_wall:?} \
         ({:.1} kcyc/s incl. compile-free steady state), checksum={xla_checksum:#010x}",
        xla_cycles as f64 / xla_wall.as_secs_f64() / 1e3
    );
    assert_eq!(xla_checksum, golden as u64, "xla checksum mismatch");
    assert_eq!(xla_cycles, native_cycles, "cycle count mismatch");

    println!("\nE2E OK: golden == native PSU == XLA/PJRT ({golden:#010x})");
    Ok(())
}
