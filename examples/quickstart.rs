//! Quickstart: parse a FIRRTL design, compile it to the OIM tensor form,
//! and simulate it with the PSU kernel — the 60-second tour of the API.
//!
//! Run: `cargo run --release --example quickstart`

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::{Design, Stimulus};
use rteaal::kernels::{build_with_oim, KernelConfig};
use rteaal::sim::Simulator;

const FIRRTL: &str = r#"
circuit Quickstart :
  module Quickstart :
    input clock : Clock
    input en : UInt<1>
    input step : UInt<8>
    output total : UInt<16>

    reg acc : UInt<16>, clock with : (reset => (reset, UInt<16>(0)))
    node widened = pad(step, 16)
    node sum = tail(add(acc, widened), 1)
    acc <= mux(en, sum, acc)
    total <= acc
"#;

fn main() -> anyhow::Result<()> {
    // 1. FIRRTL text -> dataflow graph
    let graph = rteaal::firrtl::parse(FIRRTL)?;
    println!("parsed '{}': {} ops, {} regs", graph.name, graph.num_ops(), graph.regs.len());

    // 2. graph -> optimized -> levelized -> OIM tensor
    let design = Design {
        name: graph.name.clone(),
        graph,
        stimulus: Stimulus::Random(7),
        default_cycles: 100_000,
        lane_init: vec![],
    };
    let compiled = compile_design(&design, CompileOpts::default());
    println!(
        "compiled in {:?}: {} layers, {} effectual ops, format B = {} bytes",
        compiled.compile_time,
        compiled.ir.depth(),
        compiled.ir.total_ops(),
        compiled.oim.format_b().total_bytes()
    );

    // 3. pick a kernel configuration and simulate
    let kernel = build_with_oim(KernelConfig::PSU, &compiled.ir, &compiled.oim);
    let mut sim = Simulator::new(kernel, design.make_stimulus());
    let stats = sim.run(100_000);
    println!("simulated {} cycles at {:.2} Mcyc/s", stats.cycles, stats.hz / 1e6);
    for (name, v) in sim.outputs() {
        println!("  {name} = {v}");
    }
    Ok(())
}
