//! RepCut-style partitioned simulation (Cascade 2): simulate a multi-core
//! design on 1/2/4/8 partitions — on the persistent worker pool, under
//! both register-ownership strategies — and report throughput,
//! replication factor and RUM cut size: the paper's Box 1 "parallelize
//! across partitions" optimization realized on the RTeAAL substrate,
//! plus the min-cut-vs-scatter cut comparison.
//!
//! Run: `cargo run --release --example parallel_scaling`

use std::time::Instant;

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::coordinator::parallel::ParallelSim;
use rteaal::designs::catalog;
use rteaal::kernels::KernelConfig;
use rteaal::partition::PartitionerKind;

fn main() -> anyhow::Result<()> {
    let d = catalog("rocket_like_4c").expect("design");
    let c = compile_design(&d, CompileOpts::default());
    println!("design {}: {} ops, {} regs", d.name, c.ir.total_ops(), c.graph.regs.len());
    let cycles = 2000u64;

    for kind in [PartitionerKind::RoundRobin, PartitionerKind::MinCut] {
        println!("partitioner: {}", kind.name());
        for parts in [1usize, 2, 4, 8] {
            let mut sim = ParallelSim::with_partitioner(&c.ir, KernelConfig::PSU, parts, kind);
            let mut stim = d.make_stimulus();
            // warm-up
            for cyc in 0..100 {
                sim.step(&stim(cyc));
            }
            let t0 = Instant::now();
            for cyc in 100..100 + cycles {
                sim.step(&stim(cyc));
            }
            let dt = t0.elapsed();
            println!(
                "  partitions={parts}: {:.2} Mcyc/s  (replication {:.2}x, cut {} pairs/cycle)",
                cycles as f64 / dt.as_secs_f64() / 1e6,
                sim.replication_factor,
                sim.cut_size(),
            );
        }
    }
    Ok(())
}
