//! Waveforms + host–DUT communication (paper §6.2): load a program result
//! mailbox over DMI, run the CPU, peek RAM back, and capture a VCD of the
//! whole session.
//!
//! Run: `cargo run --release --example waveform_dmi`

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::tiny_cpu::{self, addi, beq, halt, lw, sw};
use rteaal::designs::{Design, Stimulus};
use rteaal::kernels::{build_with_oim, KernelConfig};
use rteaal::sim::dmi::DmiHost;
use rteaal::sim::vcd::VcdWriter;

fn main() -> anyhow::Result<()> {
    // DUT: spin on a mailbox flag, then compute RAM[10] * 3 into RAM[0]
    let prog = vec![
        lw(2, 0, 11),
        beq(2, 0, 0),
        lw(1, 0, 10),
        add3(1),
        sw(1, 0, 0),
        halt(),
    ];
    let graph = tiny_cpu::tiny_cpu(&prog);
    let design = Design {
        name: "dmi_demo".into(),
        graph,
        stimulus: Stimulus::Zero,
        default_cycles: 100,
        lane_init: vec![],
    };
    // waveform mode: no mux fusion so named signals survive (§6.2)
    let c = compile_design(&design, CompileOpts { fuse: false });
    let mut kernel = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);
    // ports are resolved by name — a design without DMI fails here,
    // with the missing port named, not mid-run
    let dmi = DmiHost::new(&c.ir).expect("tiny_cpu exposes the dmi ports");

    std::fs::create_dir_all("results")?;
    let mut vcd = VcdWriter::create(&c.ir, std::path::Path::new("results/dmi_session.vcd"))?;

    // host session
    dmi.load(kernel.as_mut(), 10, &[14]);
    dmi.load(kernel.as_mut(), 11, &[1]);
    let idle = vec![0u64; c.ir.input_slots.len()];
    let mut cycle = 0u64;
    loop {
        kernel.step(&idle);
        cycle += 1;
        vcd.sample(cycle, kernel.slots())?;
        if kernel.outputs().iter().any(|(n, v)| n == "halted" && *v == 1) {
            break;
        }
        assert!(cycle < 1000);
    }
    vcd.finish()?;
    let result = dmi.peek(kernel.as_mut(), 0);
    println!("DUT halted after {cycle} cycles; RAM[0] = {result} (expected 42)");
    println!("waveform written to results/dmi_session.vcd");
    assert_eq!(result, 42);
    Ok(())
}

/// r1 = r1 + r1 + r1 via two adds packed as one pseudo-instruction slot
/// is not possible — emit `addi r1, r1, 28` instead (14*3 = 14+28).
fn add3(r: u32) -> u32 {
    addi(r, r, 28)
}
