"""L2: the tensorized simulation-cycle model (build-time jax).

One simulation cycle of the dense cascade encoding:

    per layer i:   gather a/b/c from LI  ->  L1 Pallas ALU
                   -> dynamic_update_slice into the layer's slot window
    then:          register commit (the `◇ : i ≡ I` connects)

**Scatter-free by contract** with `rust/src/tensor/export.rs`: the slot
layout makes every update contiguous (inputs at 0, registers at
`num_inputs`, layer i's outputs at `sources_end + i*max_ops`), because
xla_extension 0.5.1 — the rust runtime's XLA — mis-executes the scatter
ops newer jax emits for `state.at[idx].set`. Gathers round-trip fine.

Layers are unrolled at trace time (static slice offsets); the cycle chunk
is unrolled too, so the lowered module is straight-line HLO — mirroring,
pleasingly, the paper's own observation that RTL simulation compiles well
to static schedules. Python never runs on the simulation path.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.alu import alu_lanes, pallas_alu

ARRAY_KEYS = [
    "opcode", "a", "b", "c", "imm", "mask", "aux",
    "commit_next", "commit_mask", "input_widths",
    "init_slots", "init_vals", "output_slots",
]


def load_encoding(path):
    """Load the dense tensor encoding exported by `rteaal export-tensors`."""
    with open(path) as f:
        enc = json.load(f)
    for k in ARRAY_KEYS:
        enc[k] = np.asarray(enc[k], dtype=np.uint32)
    return enc


def build_cycle_fn(enc, use_pallas=True, block=128, chunk=8):
    """Build `cycle_chunk(state, inputs) -> (state', outputs)`.

    state:   u32[num_slots]
    inputs:  u32[chunk, max(num_inputs, 1)]
    outputs: u32[chunk, num_outputs]
    """
    L, M = int(enc["num_layers"]), int(enc["max_ops"])
    S0 = int(enc["sources_end"])
    n_inputs = int(enc["num_inputs"])
    layer_arrays = [
        tuple(jnp.asarray(enc[k].reshape(L, M)[i]) for k in ("opcode", "a", "b", "c", "imm", "mask", "aux"))
        for i in range(L)
    ]
    commit_next = jnp.asarray(enc["commit_next"])
    commit_mask = jnp.asarray(enc["commit_mask"])
    widths = enc["input_widths"].astype(np.uint64)
    input_mask = jnp.asarray(
        np.where(widths >= 32, 0xFFFFFFFF, (1 << widths) - 1).astype(np.uint32)
    )
    output_slots = jnp.asarray(enc["output_slots"])

    alu = (lambda *args: pallas_alu(*args, block=min(block, M))) if use_pallas else alu_lanes

    def cycle(state, inp_row):
        if n_inputs > 0:
            masked = inp_row[:n_inputs] & input_mask
            state = jax.lax.dynamic_update_slice(state, masked, (0,))
        # layers unrolled: static offsets, contiguous updates
        for i, (opcode, a_idx, b_idx, c_idx, imm, mask, aux) in enumerate(layer_arrays):
            vals = alu(opcode, state[a_idx], state[b_idx], state[c_idx], imm, mask, aux)
            state = jax.lax.dynamic_update_slice(state, vals, (S0 + i * M,))
        # register commit: gather next-state values, contiguous write
        if len(enc["commit_next"]) > 0:
            next_vals = state[commit_next] & commit_mask
            state = jax.lax.dynamic_update_slice(state, next_vals, (n_inputs,))
        return state, state[output_slots]

    def cycle_chunk(state, inputs):
        outs = []
        for k in range(chunk):
            state, o = cycle(state, inputs[k])
            outs.append(o)
        return state, jnp.stack(outs)

    return cycle_chunk


def initial_state(enc):
    state = np.zeros(enc["num_slots"], dtype=np.uint32)
    for s, v in zip(enc["init_slots"], enc["init_vals"]):
        state[s] = v
    return state
