"""AOT export: lower the L2 cycle-chunk model to HLO *text* + metadata.

HLO text (NOT `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the rust side's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py and DESIGN.md).

Usage:
    python -m compile.aot --tensors ../artifacts/<d>.tensors.json \
                          --out ../artifacts/<d> [--chunk 32] [--no-pallas]
"""

import argparse
import json

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import build_cycle_fn, load_encoding


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print_large_constants — the default printer elides big
    # constant arrays ("{1, 2, ...}"), and xla_extension 0.5.1's text
    # parser silently fills the gap with garbage. The design's index
    # tensors are exactly such constants.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ... and the default printer emits metadata attributes (source_end_line)
    # the 0.5.1 parser rejects.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_design(tensors_path, chunk=8, use_pallas=True, block=128):
    enc = load_encoding(tensors_path)
    assert enc["max_ops"] % block == 0 or enc["max_ops"] < block, \
        "exporter must pad max_ops to the Pallas block"
    fn = build_cycle_fn(enc, use_pallas=use_pallas, block=block, chunk=chunk)
    n_inputs = max(int(enc["num_inputs"]), 1)
    state_spec = jax.ShapeDtypeStruct((int(enc["num_slots"]),), jnp.uint32)
    inputs_spec = jax.ShapeDtypeStruct((chunk, n_inputs), jnp.uint32)
    lowered = jax.jit(fn).lower(state_spec, inputs_spec)
    meta = {
        "name": enc["name"],
        "num_slots": int(enc["num_slots"]),
        "chunk": chunk,
        "num_inputs": int(enc["num_inputs"]),
        "num_outputs": int(len(enc["output_slots"])),
        "pallas": bool(use_pallas),
        "block": block,
    }
    return to_hlo_text(lowered), meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tensors", required=True, help="dense tensor encoding JSON")
    ap.add_argument("--out", required=True, help="output basename (writes .hlo.txt and .meta.json)")
    ap.add_argument("--chunk", type=int, default=8, help="cycles per PJRT call")
    ap.add_argument("--block", type=int, default=128, help="Pallas S-tile")
    ap.add_argument("--no-pallas", action="store_true", help="plain-jnp ALU (ablation)")
    args = ap.parse_args()

    hlo, meta = lower_design(
        args.tensors, chunk=args.chunk, use_pallas=not args.no_pallas, block=args.block
    )
    hlo_path = f"{args.out}.hlo.txt"
    with open(hlo_path, "w") as f:
        f.write(hlo)
    with open(f"{args.out}.meta.json", "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {hlo_path} ({len(hlo)} chars), chunk={args.chunk}, pallas={not args.no_pallas}")


if __name__ == "__main__":
    main()
