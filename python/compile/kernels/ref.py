"""Pure-numpy oracle for the L1 multi-op ALU and the cycle semantics.

This is the correctness anchor of the Python side: the Pallas kernel
(`alu.py`) and the L2 model (`model.py`) are tested against these
definitions, and these definitions mirror `rust/src/tensor/ir.rs::eval_rec`
exactly (u32 flavour).
"""

import numpy as np

# Executor opcode numbering — MUST match rust/src/tensor/ir.rs::KOp.
OPS = [
    "add", "sub", "mul", "div", "rem",
    "lt", "leq", "gt", "geq", "eq", "neq",
    "and", "or", "xor",
    "not", "neg",
    "andrk", "orr", "xorr",
    "shli", "shri",
    "dshl", "dshr",
    "cat", "mux", "copy", "muxchain",
]
OPCODE = {name: i for i, name in enumerate(OPS)}
NUM_OPS = len(OPS)  # 27 (muxchain never appears in XLA exports)


def ref_alu_scalar(op, a, b, c, imm, mask, aux):
    """Scalar u32 reference for one op (python ints)."""
    M32 = 0xFFFFFFFF
    a, b, c = a & M32, b & M32, c & M32
    name = OPS[op]
    if name == "add":
        r = a + b
    elif name == "sub":
        r = a - b
    elif name == "mul":
        r = a * b
    elif name == "div":
        r = 0 if b == 0 else a // b
    elif name == "rem":
        r = 0 if b == 0 else a % b
    elif name == "lt":
        r = int(a < b)
    elif name == "leq":
        r = int(a <= b)
    elif name == "gt":
        r = int(a > b)
    elif name == "geq":
        r = int(a >= b)
    elif name == "eq":
        r = int(a == b)
    elif name == "neq":
        r = int(a != b)
    elif name == "and":
        r = a & b
    elif name == "or":
        r = a | b
    elif name == "xor":
        r = a ^ b
    elif name == "not":
        r = ~a
    elif name == "neg":
        r = -a
    elif name == "andrk":
        r = int(a == (aux & M32))
    elif name == "orr":
        r = int(a != 0)
    elif name == "xorr":
        r = bin(a).count("1") & 1
    elif name == "shli":
        r = a << imm if imm < 32 else 0
    elif name == "shri":
        r = a >> imm if imm < 32 else 0
    elif name == "dshl":
        r = 0 if b >= 32 else a << b
    elif name == "dshr":
        r = 0 if b >= 32 else a >> b
    elif name == "cat":
        r = ((a << imm) | b) if imm < 32 else b
    elif name == "mux":
        r = b if a != 0 else c
    elif name == "copy":
        r = a
    else:
        raise ValueError(f"op {name} not supported in the u32 tensor ISA")
    return r & mask & M32


def ref_alu(opcode, a, b, c, imm, mask, aux):
    """Vectorized numpy reference: element-wise multi-op ALU."""
    out = np.zeros_like(np.asarray(a), dtype=np.uint32)
    for i in range(len(out)):
        out[i] = ref_alu_scalar(
            int(opcode[i]), int(a[i]), int(b[i]), int(c[i]),
            int(imm[i]), int(mask[i]), int(aux[i]),
        )
    return out


class RefCycleSim:
    """Pure-python cycle simulator over the dense tensor encoding
    (mirrors rust's IrSim; used to validate the jax model).

    Layout contract (see rust/src/tensor/export.rs): inputs at slots
    [0, num_inputs), registers at [num_inputs, +num_regs), layer i's
    outputs at [sources_end + i*max_ops, +max_ops)."""

    def __init__(self, enc):
        self.enc = enc
        self.state = np.zeros(enc["num_slots"], dtype=np.uint32)
        for s, v in zip(enc["init_slots"], enc["init_vals"]):
            self.state[s] = v

    def step(self, inputs):
        enc = self.enc
        for i in range(enc["num_inputs"]):
            w = enc["input_widths"][i]
            m = 0xFFFFFFFF if w >= 32 else (1 << w) - 1
            self.state[i] = np.uint32(int(inputs[i]) & m)
        L, M, S0 = enc["num_layers"], enc["max_ops"], enc["sources_end"]
        for layer in range(L):
            lo, hi = layer * M, (layer + 1) * M
            a = self.state[enc["a"][lo:hi]]
            b = self.state[enc["b"][lo:hi]]
            c = self.state[enc["c"][lo:hi]]
            out = ref_alu(enc["opcode"][lo:hi], a, b, c,
                          enc["imm"][lo:hi], enc["mask"][lo:hi], enc["aux"][lo:hi])
            self.state[S0 + layer * M:S0 + (layer + 1) * M] = out
        base = enc["num_inputs"]
        for i, (n, m) in enumerate(zip(enc["commit_next"], enc["commit_mask"])):
            self.state[base + i] = self.state[n] & np.uint32(m)

    def outputs(self):
        return [int(self.state[s]) for s in self.enc["output_slots"]]
