"""L1 Pallas kernel: the batched multi-operation ALU.

The compute hot-spot of the tensorized cycle: for a layer's S lanes,
``out[s] = mask[s] & op[opcode[s]](a[s], b[s], c[s])``. Lanes are tiled
over S with a BlockSpec so the kernel streams VMEM-sized blocks; the
opcode select tree is lane-uniform (every lane computes all candidate
results, then selects) — the right shape for a TPU VPU, and exactly how
a sparse-tensor-algebra accelerator would execute the `op_u/op_r` actions
of the cascade.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls (see DESIGN.md §Hardware-Adaptation); the kernel still
lowers into the same HLO module the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# default S-tile; multiples of 128 lanes (VPU width)
BLOCK_S = 512


def _candidates(op, a, b, c, imm, mask, aux):
    """All candidate results, lane-wise (u32 semantics)."""
    zero = jnp.zeros_like(a)
    one = jnp.ones_like(a)
    bool2u = lambda x: x.astype(jnp.uint32)  # noqa: E731
    shamt_b = jnp.minimum(b, 31).astype(jnp.uint32)
    b_ok = b < 32
    imm5 = jnp.minimum(imm, 31).astype(jnp.uint32)

    cands = [
        a + b,                                            # add
        a - b,                                            # sub
        a * b,                                            # mul
        jnp.where(b == 0, zero, a // jnp.maximum(b, one)),  # div
        jnp.where(b == 0, zero, a % jnp.maximum(b, one)),   # rem
        bool2u(a < b),                                    # lt
        bool2u(a <= b),                                   # leq
        bool2u(a > b),                                    # gt
        bool2u(a >= b),                                   # geq
        bool2u(a == b),                                   # eq
        bool2u(a != b),                                   # neq
        a & b,                                            # and
        a | b,                                            # or
        a ^ b,                                            # xor
        ~a,                                               # not
        zero - a,                                         # neg
        bool2u(a == aux),                                 # andrk
        bool2u(a != 0),                                   # orr
        jax.lax.population_count(a) & one,                # xorr
        a << imm5,                                        # shli
        a >> imm5,                                        # shri
        jnp.where(b_ok, a << shamt_b, zero),              # dshl
        jnp.where(b_ok, a >> shamt_b, zero),              # dshr
        (a << imm5) | b,                                  # cat
        jnp.where(a != 0, b, c),                          # mux
        a,                                                # copy
        zero,                                             # muxchain (never exported)
    ]
    return cands


def alu_lanes(op, a, b, c, imm, mask, aux):
    """Lane-wise multi-op ALU in plain jnp (used inside the kernel and as
    the L2 fallback when Pallas is disabled)."""
    cands = _candidates(op, a, b, c, imm, mask, aux)
    stack = jnp.stack(cands, axis=0)  # [NUM_OPS, S]
    sel = jnp.take_along_axis(stack, op[None, :].astype(jnp.int32), axis=0)[0]
    return sel & mask


def _alu_kernel(op_ref, a_ref, b_ref, c_ref, imm_ref, mask_ref, aux_ref, out_ref):
    out_ref[...] = alu_lanes(
        op_ref[...], a_ref[...], b_ref[...], c_ref[...],
        imm_ref[...], mask_ref[...], aux_ref[...],
    )


@functools.partial(jax.jit, static_argnames=("block",))
def pallas_alu(op, a, b, c, imm, mask, aux, block=BLOCK_S):
    """The Pallas entry point. S must be a multiple of `block` (the AOT
    exporter pads layers accordingly)."""
    s = a.shape[0]
    block = min(block, s)
    assert s % block == 0, f"S={s} not a multiple of block={block}"
    grid = (s // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _alu_kernel,
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((s,), jnp.uint32),
        interpret=True,
    )(op, a, b, c, imm, mask, aux)
