"""L2 cycle model vs the pure-python cycle simulator, on a hand-built
dense encoding (a 2-layer counter) and on randomized encodings, using the
scatter-free slot layout (see rust/src/tensor/export.rs)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import build_cycle_fn, initial_state


def counter_encoding():
    """Layout: slot0=en(input) slot1=reg slot2=const1; layer0 out at 3
    (add = reg+1), layer1 out at 4 (mux = en ? add : reg); commit reg<=4."""
    O = ref.OPCODE
    enc = {
        "name": "counter_enc",
        "num_slots": 5,
        "num_layers": 2,
        "max_ops": 1,
        "sources_end": 3,
        "num_inputs": 1,
        "num_regs": 1,
        "opcode": [O["add"], O["mux"]],
        "a": [1, 0],
        "b": [2, 3],
        "c": [0, 1],
        "imm": [0, 0],
        "mask": [0xF, 0xF],
        "aux": [0, 0],
        "commit_next": [4],
        "commit_mask": [0xF],
        "input_widths": [1],
        "init_slots": [2],
        "init_vals": [1],
        "output_slots": [1],
        "output_names": ["count"],
    }
    return {k: (np.asarray(v, dtype=np.uint32) if isinstance(v, list) and k != "output_names" else v)
            for k, v in enc.items()}


def run_chunked(enc, inputs, use_pallas, block=128):
    chunk = inputs.shape[0]
    fn = build_cycle_fn(enc, use_pallas=use_pallas, block=block, chunk=chunk)
    state = np.asarray(initial_state(enc))
    state, outs = fn(state, np.asarray(inputs, dtype=np.uint32))
    return np.asarray(state), np.asarray(outs)


def test_counter_counts():
    enc = counter_encoding()
    inputs = np.ones((5, 1), dtype=np.uint32)
    _, outs = run_chunked(enc, inputs, use_pallas=False)
    np.testing.assert_array_equal(outs[:, 0], [1, 2, 3, 4, 5])


def test_counter_wraps_at_mask():
    enc = counter_encoding()
    inputs = np.ones((20, 1), dtype=np.uint32)
    _, outs = run_chunked(enc, inputs, use_pallas=False)
    assert outs[-1, 0] == 20 % 16


def test_pallas_and_jnp_agree_on_counter():
    enc = counter_encoding()
    inputs = np.ones((8, 1), dtype=np.uint32)
    _, a = run_chunked(enc, inputs, use_pallas=False)
    _, b = run_chunked(enc, inputs, use_pallas=True)
    np.testing.assert_array_equal(a, b)


def random_encoding(rng, n_layers=3, m=6):
    """A random well-formed encoding in the contiguous layout."""
    O = ref.OPCODE
    legal = [O[x] for x in ("add", "sub", "and", "or", "xor", "mux", "copy", "not",
                            "eq", "lt", "shli", "cat")]
    n_inputs, n_regs, n_consts = 1, 2, 2
    s0 = n_inputs + n_regs + n_consts
    num_slots = s0 + n_layers * m
    enc = {
        "name": "rand",
        "num_slots": num_slots,
        "num_layers": n_layers,
        "max_ops": m,
        "sources_end": s0,
        "num_inputs": n_inputs,
        "num_regs": n_regs,
        "opcode": [], "a": [], "b": [], "c": [], "imm": [], "mask": [], "aux": [],
        "commit_next": [],
        "commit_mask": [0xFFFFFFFF, 0xFFFF],
        "input_widths": [16],
        "init_slots": [3, 4],
        "init_vals": [int(rng.integers(0, 2**16)), int(rng.integers(0, 2**16))],
        "output_slots": [],
        "output_names": [],
    }
    readable = list(range(s0))
    for layer in range(n_layers):
        base = s0 + layer * m
        for _ in range(m):
            enc["opcode"].append(int(rng.choice(legal)))
            enc["a"].append(int(rng.choice(readable)))
            enc["b"].append(int(rng.choice(readable)))
            enc["c"].append(int(rng.choice(readable)))
            enc["imm"].append(int(rng.integers(0, 16)))
            enc["mask"].append(0xFFFFFFFF)
            enc["aux"].append(0)
        readable += list(range(base, base + m))
    last = num_slots - 1
    enc["commit_next"] = [last, s0]
    enc["output_slots"] = [last, 1, 2]
    enc["output_names"] = ["o0", "o1", "o2"]
    return {k: (np.asarray(v, dtype=np.uint32) if isinstance(v, list) and k != "output_names" else v)
            for k, v in enc.items()}


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_model_matches_ref_cycle_sim(seed):
    rng = np.random.default_rng(seed)
    enc = random_encoding(rng)
    cycles = 6
    inputs = rng.integers(0, 2**16, (cycles, 1)).astype(np.uint32)
    _, outs = run_chunked(enc, inputs, use_pallas=True)
    sim = ref.RefCycleSim(enc)
    for cyc in range(cycles):
        sim.step(inputs[cyc])
        np.testing.assert_array_equal(outs[cyc], sim.outputs(), err_msg=f"cycle {cyc}")
