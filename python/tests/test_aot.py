"""AOT pipeline smoke tests: lowering produces parseable HLO text with the
right interface, for both the Pallas and plain-jnp ALU variants. Also
checks the scatter-free contract: the lowered module must not contain
scatter ops (xla_extension 0.5.1 mis-executes them — DESIGN.md §Runtime)."""

import json

import numpy as np
import pytest

from compile import aot
from tests.test_model import counter_encoding


@pytest.fixture()
def tensors_file(tmp_path):
    enc = counter_encoding()
    p = tmp_path / "counter.tensors.json"
    with open(p, "w") as f:
        json.dump({k: (v.tolist() if isinstance(v, np.ndarray) else v) for k, v in enc.items()}, f)
    return p


@pytest.mark.parametrize("use_pallas", [True, False])
def test_lower_design_emits_hlo_text(tensors_file, use_pallas):
    hlo, meta = aot.lower_design(tensors_file, chunk=4, use_pallas=use_pallas, block=128)
    assert hlo.startswith("HloModule")
    assert meta["num_slots"] == 5
    assert meta["chunk"] == 4
    assert meta["num_inputs"] == 1
    assert meta["num_outputs"] == 1
    assert "u32[5]" in hlo  # state
    assert "u32[4,1]" in hlo  # inputs [chunk, n_inputs]
    # the 0.5.1-compatibility contract
    assert "scatter" not in hlo, "lowered module must be scatter-free"


def test_lowered_module_executes_in_jax(tensors_file):
    """Sanity: the exact function we lower computes the counter sequence."""
    from compile.model import build_cycle_fn, initial_state, load_encoding

    enc = load_encoding(tensors_file)
    fn = build_cycle_fn(enc, use_pallas=True, chunk=4)
    state = np.asarray(initial_state(enc))
    _, outs = fn(state, np.ones((4, 1), dtype=np.uint32))
    np.testing.assert_array_equal(np.asarray(outs)[:, 0], [1, 2, 3, 4])
