"""L1 Pallas kernel vs pure-numpy oracle — the core correctness signal.

Hypothesis sweeps lane counts, opcode mixes and operand values (including
the nasty edges: division by zero, over-shifts, zero masks).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.alu import alu_lanes, pallas_alu
from compile.kernels import ref

# opcodes legal in the u32 tensor ISA (muxchain excluded)
LEGAL_OPS = list(range(ref.NUM_OPS - 1))


def make_case(rng, n):
    op = rng.integers(0, len(LEGAL_OPS), n).astype(np.uint32)
    a = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    c = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    imm = rng.integers(0, 32, n).astype(np.uint32)
    widths = rng.integers(1, 33, n)
    mask = np.where(widths >= 32, 0xFFFFFFFF, (1 << widths) - 1).astype(np.uint32)
    aux = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    # sprinkle edge operands
    b[::7] = 0          # div/rem by zero
    b[1::11] = 40       # dynamic over-shift
    mask[::13] = 0      # dead lanes
    return op, a, b, c, imm, mask, aux


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size_mult=st.integers(1, 4))
def test_pallas_matches_ref(seed, size_mult):
    n = 128 * size_mult  # pallas block divides S
    rng = np.random.default_rng(seed)
    case = make_case(rng, n)
    got = np.asarray(pallas_alu(*[np.asarray(x) for x in case], block=128))
    want = ref.ref_alu(*case)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_jnp_fallback_matches_ref(seed):
    rng = np.random.default_rng(seed)
    case = make_case(rng, 96)  # non-multiple of 128: fallback path
    got = np.asarray(alu_lanes(*[np.asarray(x) for x in case]))
    want = ref.ref_alu(*case)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("opname", ref.OPS[:-1])
def test_each_opcode_individually(opname):
    n = 128
    rng = np.random.default_rng(hash(opname) % 2**32)
    op = np.full(n, ref.OPCODE[opname], dtype=np.uint32)
    a = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 64, n).astype(np.uint32)  # small: shift amounts
    c = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    imm = rng.integers(0, 32, n).astype(np.uint32)
    mask = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    aux = a.copy()  # andrk compares equal on half the lanes
    aux[::2] ^= 1
    got = np.asarray(pallas_alu(op, a, b, c, imm, mask, aux, block=128))
    want = ref.ref_alu(op, a, b, c, imm, mask, aux)
    np.testing.assert_array_equal(got, want, err_msg=opname)


def test_block_sweep():
    """Kernel result must be independent of the BlockSpec tiling."""
    rng = np.random.default_rng(42)
    case = make_case(rng, 512)
    ref_out = ref.ref_alu(*case)
    for block in (128, 256, 512):
        got = np.asarray(pallas_alu(*[np.asarray(x) for x in case], block=block))
        np.testing.assert_array_equal(got, ref_out, err_msg=f"block={block}")
