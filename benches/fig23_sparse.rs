//! Bench: Fig 23 sparse activity-masked batching sweep (ours, beyond the
//! paper — see coordinator::report::fig23_sparse). Quick by default; set
//! RTEAAL_FULL=1 for full-length runs.
//!
//! The grid is measured **once** (`report::fig23_measure`) and reused for
//! both the rendered table and the per-design skip-statistics JSON dump
//! (`results/fig23_skip.json`).
//!
//! Acceptance check built in: dynamic sparsity must pay on the unrolled
//! end — at a 5% per-lane toggle rate the sparse TI kernel's aggregate
//! lane-cycles/sec must exceed the dense TI kernel's under the same
//! stimulus, with a reported skip-rate above 50% (the bookkeeping
//! amortizes over B = 64 lanes on the shallow `alu_farm_64` workload).

rteaal::install_tracking_alloc!();

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::coordinator::report::{self, FIG23_LANES};
use rteaal::coordinator::sweep;
use rteaal::designs::catalog;
use rteaal::kernels::KernelConfig;
use rteaal::util::json::{obj, Json};

fn main() {
    let ctx = report::Ctx::from_env();
    let points = report::fig23_measure(&ctx);
    let table = report::fig23_table(&points);
    println!("{}", table.render());
    if let Ok(p) = table.save_csv("fig23") {
        eprintln!("csv: {}", p.display());
    }

    // per-design skip statistics as JSON, from the same measurements
    let mut designs_json: std::collections::BTreeMap<String, Json> = Default::default();
    for p in &points {
        let per_kernel = designs_json
            .entry(p.design.to_string())
            .or_insert_with(|| Json::Obj(Default::default()));
        let Json::Obj(kernels) = per_kernel else { unreachable!() };
        let rates: std::collections::BTreeMap<String, Json> = p
            .sparse
            .iter()
            .map(|(rate, sp)| {
                let key = if p.toggleable {
                    format!("toggle_{:.0}pct", rate * 100.0)
                } else {
                    "idle".to_string()
                };
                let cell = Json::Obj(
                    [
                        ("skip_rate".to_string(), Json::Num(sp.skip_rate.unwrap_or(0.0))),
                        ("lane_cycles_per_sec".to_string(), Json::Num(sp.hz)),
                        ("dense_lane_cycles_per_sec".to_string(), Json::Num(p.dense.hz)),
                    ]
                    .into_iter()
                    .collect(),
                );
                (key, cell)
            })
            .collect();
        kernels.insert(p.kernel.name().to_string(), Json::Obj(rates));
    }
    let root = obj(vec![
        ("lanes", Json::Int(FIG23_LANES as i64)),
        ("designs", Json::Obj(designs_json)),
    ]);
    let dir = std::path::Path::new("results");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join("fig23_skip.json");
        if std::fs::write(&path, root.to_string()).is_ok() {
            eprintln!("json: {}", path.display());
        }
    }

    // acceptance: sparse TI beats dense TI at a 5% toggle rate with a
    // skip-rate above 50% (alu_farm_64, B = 64)
    let d = catalog("alu_farm_64").expect("catalog design");
    let c = compile_design(&d, CompileOpts::default());
    let lanes = 64;
    let cycles = 1000;
    let rate = 0.05;
    let dense = sweep::measure_kernel_lanes_toggle(&d, &c, KernelConfig::TI, lanes, cycles, rate);
    let sparse = sweep::measure_kernel_lanes_sparse(&d, &c, KernelConfig::TI, lanes, cycles, rate);
    let skip = sparse.skip_rate.unwrap_or(0.0);
    println!(
        "TI @5% toggle, B={lanes}: dense {:.2} M lane-cyc/s, sparse {:.2} M lane-cyc/s ({:.2}x), skip-rate {:.1}%",
        dense.hz / 1e6,
        sparse.hz / 1e6,
        sparse.hz / dense.hz,
        100.0 * skip
    );
    assert!(
        skip > 0.5,
        "skip-rate {skip:.3} should exceed 0.5 at a 5% per-lane toggle rate"
    );
    assert!(
        sparse.hz > dense.hz,
        "sparse TI aggregate throughput ({:.2e}) should exceed dense TI ({:.2e}) at 5% toggle",
        sparse.hz,
        dense.hz
    );
}
