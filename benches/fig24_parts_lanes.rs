//! Bench: Fig 24 partitions × lanes sweep (ours, beyond the paper — see
//! coordinator::report::fig24_parts_lanes). Quick by default; set
//! RTEAAL_FULL=1 for full-length runs.
//!
//! The grid — now (kernel × partitioner × P × B) — is measured **once**
//! (`report::fig24_measure`) and reused for both the rendered table and
//! the JSON dump (`results/fig24_parts_lanes.json`), which additionally
//! records the per-partitioning RUM cut (`cut_regs`) and the sparse
//! (partition- **and** group-skipping) measurement on `alu_farm_64`,
//! with both skip rates.
//!
//! Acceptance checks built in:
//! * composing thread-level and data-level parallelism must pay — the TI
//!   kernel at P=4 × B=8 must achieve higher *aggregate* lane-cycles/sec
//!   than P=1 × B=1 on `gemmini_like_8` (wall-clock: authoritative on
//!   quiet hardware, informational on shared CI runners);
//! * the min-cut partitioner must beat round-robin's scatter on the
//!   structured systolic array — `MinCut` cut ≤ `RoundRobin` cut on
//!   `gemmini_like_8` at P ∈ {2, 4} (deterministic; the strict-< form is
//!   also enforced as a cargo test in `partition::tests`);
//! * the sparse ParallelSim must skip idle partitions — with the
//!   stimulus frozen after cycle 0 on `alu_farm_64`, the partition-cycle
//!   skip-rate must exceed 50% (deterministic; also enforced as a cargo
//!   test in `coordinator::parallel`);
//! * the group-masked sparse kernels *inside* the partitions must skip
//!   too — on the same frozen `alu_farm_64` run at P=4 × B=8, the
//!   composed group-level op-lane skip-rate must exceed 50%
//!   (deterministic; partition-skipped cycles count as skipped op-lanes).

rteaal::install_tracking_alloc!();

use std::collections::BTreeMap;

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::coordinator::report::{self, FIG24_DESIGN, FIG24_PARTS};
use rteaal::coordinator::sweep;
use rteaal::designs::catalog;
use rteaal::kernels::KernelConfig;
use rteaal::partition::PartitionerKind;
use rteaal::util::json::{obj, Json};

fn main() {
    let ctx = report::Ctx::from_env();
    let points = report::fig24_measure(&ctx);
    let table = report::fig24_table(&points);
    println!("{}", table.render());
    if let Ok(p) = table.save_csv("fig24_parts_lanes") {
        eprintln!("csv: {}", p.display());
    }

    // sparse partition-skipping measurement on the mostly-quiescent farm
    let farm = catalog("alu_farm_64").expect("catalog design");
    let cfarm = compile_design(&farm, CompileOpts::default());
    let (parts, lanes, cycles) = (4usize, 8usize, 1000u64);
    let sparse = sweep::measure_kernel_parts_lanes_sparse(
        &farm,
        &cfarm,
        KernelConfig::PSU,
        parts,
        lanes,
        cycles,
        0.0,
        PartitionerKind::MinCut,
    );
    let dense = sweep::measure_kernel_parts_lanes(
        &farm,
        &cfarm,
        KernelConfig::PSU,
        parts,
        lanes,
        cycles,
        PartitionerKind::MinCut,
    );

    // the grid (throughput and cut per partitioner) plus the sparse farm
    // point as JSON
    let mut kernels_json: BTreeMap<String, Json> = Default::default();
    let mut cut_json: BTreeMap<String, Json> = Default::default();
    for p in &points {
        let per_kernel = kernels_json
            .entry(p.kernel.name().to_string())
            .or_insert_with(|| Json::Obj(Default::default()));
        let Json::Obj(per_pk) = per_kernel else { unreachable!() };
        let per_cells = per_pk
            .entry(p.partitioner.name().to_string())
            .or_insert_with(|| Json::Obj(Default::default()));
        let Json::Obj(cells) = per_cells else { unreachable!() };
        for (b, sp) in &p.cells {
            cells.insert(format!("P{}xB{}", p.parts, b), Json::Num(sp.hz));
        }
        let per_cut = cut_json
            .entry(p.partitioner.name().to_string())
            .or_insert_with(|| Json::Obj(Default::default()));
        let Json::Obj(cuts) = per_cut else { unreachable!() };
        cuts.insert(format!("P{}", p.parts), Json::Int(p.cut_regs as i64));
    }
    let root = obj(vec![
        ("design", Json::Str(FIG24_DESIGN.to_string())),
        ("lane_cycles_per_sec", Json::Obj(kernels_json)),
        ("cut_regs", Json::Obj(cut_json)),
        (
            "sparse_alu_farm_64",
            obj(vec![
                ("parts", Json::Int(parts as i64)),
                ("lanes", Json::Int(lanes as i64)),
                ("partitioner", Json::Str("mincut".to_string())),
                ("toggle_rate", Json::Num(0.0)),
                ("partition_skip_rate", Json::Num(sparse.skip_rate.unwrap_or(0.0))),
                ("group_skip_rate", Json::Num(sparse.group_skip_rate.unwrap_or(0.0))),
                ("lane_cycles_per_sec", Json::Num(sparse.hz)),
                ("dense_lane_cycles_per_sec", Json::Num(dense.hz)),
            ]),
        ),
    ]);
    let dir = std::path::Path::new("results");
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join("fig24_parts_lanes.json");
        if std::fs::write(&path, root.to_string()).is_ok() {
            eprintln!("json: {}", path.display());
        }
    }

    // acceptance: the min-cut RUM cut never exceeds round-robin's on the
    // systolic array at P in {2, 4} (deterministic — no wall clock)
    let cut_of = |pk: PartitionerKind, parts: usize| -> usize {
        points
            .iter()
            .find(|p| p.partitioner == pk && p.parts == parts)
            .map(|p| p.cut_regs)
            .expect("grid covers every (partitioner, parts) point")
    };
    for &parts in FIG24_PARTS.iter().filter(|&&p| p > 1) {
        let rr = cut_of(PartitionerKind::RoundRobin, parts);
        let mc = cut_of(PartitionerKind::MinCut, parts);
        println!(
            "RUM cut on {FIG24_DESIGN} at P={parts}: rr {rr} regs, mincut {mc} regs ({:.1}%)",
            100.0 * mc as f64 / rr.max(1) as f64
        );
        assert!(
            mc <= rr,
            "P={parts}: mincut cut {mc} must not exceed round-robin cut {rr}"
        );
    }

    // acceptance: P=4 × B=8 aggregate beats P=1 × B=1 on the TI kernel
    let d = catalog(FIG24_DESIGN).expect("catalog design");
    let c = compile_design(&d, CompileOpts::default());
    let base = sweep::measure_kernel_parts_lanes(
        &d,
        &c,
        KernelConfig::TI,
        1,
        1,
        cycles,
        PartitionerKind::MinCut,
    );
    let scaled = sweep::measure_kernel_parts_lanes(
        &d,
        &c,
        KernelConfig::TI,
        4,
        8,
        cycles,
        PartitionerKind::MinCut,
    );
    println!(
        "TI aggregate throughput on {FIG24_DESIGN}: P1xB1 {:.2} M lane-cyc/s, P4xB8 {:.2} M lane-cyc/s ({:.2}x)",
        base.hz / 1e6,
        scaled.hz / 1e6,
        scaled.hz / base.hz
    );
    assert!(
        scaled.hz > base.hz,
        "P=4 x B=8 aggregate throughput ({:.2e}) should exceed P=1 x B=1 ({:.2e}) on TI",
        scaled.hz,
        base.hz
    );

    // acceptance: idle partitions are skipped on the frozen-stimulus farm
    let skip = sparse.skip_rate.unwrap_or(0.0);
    let group_skip = sparse.group_skip_rate.unwrap_or(0.0);
    println!(
        "sparse ParallelSim on alu_farm_64 (P={parts}, B={lanes}, frozen stimulus): \
         partition skip-rate {:.1}%, group skip-rate {:.1}%, \
         {:.2} M lane-cyc/s vs dense {:.2} M lane-cyc/s",
        100.0 * skip,
        100.0 * group_skip,
        sparse.hz / 1e6,
        dense.hz / 1e6
    );
    assert!(
        skip > 0.5,
        "partition skip-rate {skip:.3} should exceed 0.5 with frozen stimulus"
    );
    // acceptance: the sparse kernels inside the partitions compose —
    // group-level op-lane skipping (partition-skipped cycles counted as
    // skipped op-lanes) must also clear 50% on the frozen farm
    assert!(
        group_skip > 0.5,
        "group-level skip-rate {group_skip:.3} should exceed 0.5 with frozen stimulus"
    );
}
