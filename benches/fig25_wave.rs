//! Bench: waveform capture cost under the activity-gated sink (ours,
//! beyond the paper — the §6.2 waveform path at batch scale). Quick by
//! default; set RTEAAL_FULL=1 for longer timed windows.
//!
//! Setup: `alu_farm_16` partitioned P = 4 × B = 8 lanes with a *frozen*
//! stimulus (toggle rate 0: inputs drawn once at cycle 0, then held), so
//! after a short warm-up every cycle is quiescent. A [`WaveSink`] is
//! attached to lane 0 in outputs mode — the `rteaal sim --parts 4 --vcd`
//! / `serve` `wave`-verb configuration.
//!
//! Acceptance checks built in:
//!
//! * **quiescent cost**: the timed (frozen) window must emit **zero**
//!   waveform bytes — a quiescent cycle is one mask test, not a scan;
//! * **throughput**: on the sparse engine, waveform-on throughput must
//!   be ≥ 80% of waveform-off on the same frozen run (the <20% wave tax
//!   the delta subsystem promises).

rteaal::install_tracking_alloc!();

use std::time::Instant;

use rteaal::coordinator::compile::{compile_design, CompileOpts, Compiled};
use rteaal::coordinator::parallel::BatchParallelSim;
use rteaal::designs::{catalog, Design};
use rteaal::kernels::KernelConfig;
use rteaal::sim::WaveSink;

const PARTS: usize = 4;
const LANES: usize = 8;

struct Run {
    /// aggregate lane-cycles per second over the timed window
    hz: f64,
    /// VCD bytes emitted during the timed window (frozen ⇒ should be 0)
    timed_bytes: usize,
    /// VCD bytes emitted during warm-up (header + first dump + drain)
    warmup_bytes: usize,
}

fn run(d: &Design, c: &Compiled, sparse: bool, wave: bool, warmup: u64, cycles: u64) -> Run {
    let mut sim = BatchParallelSim::new(&c.ir, KernelConfig::PSU, PARTS, LANES, sparse);
    let mut sink = if wave {
        Some(WaveSink::attach_outputs(&c.ir, 0, Vec::new()).expect("Vec sink"))
    } else {
        None
    };
    let mut stim = d.make_lane_stimulus_toggle(LANES, 0.0);
    let mut buf: Vec<(String, u64)> = Vec::new();
    let mut cyc = 0u64;
    for _ in 0..warmup {
        sim.step(&stim(cyc));
        cyc += 1;
        if let Some(s) = sink.as_mut() {
            s.sample_parallel(cyc, &sim, &mut buf).expect("Vec writes are infallible");
        }
    }
    let warmup_bytes = sink.as_mut().map_or(0, |s| s.take_chunk().len());
    let t0 = Instant::now();
    for _ in 0..cycles {
        sim.step(&stim(cyc));
        cyc += 1;
        if let Some(s) = sink.as_mut() {
            s.sample_parallel(cyc, &sim, &mut buf).expect("Vec writes are infallible");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let timed_bytes = sink.as_mut().map_or(0, |s| s.take_chunk().len());
    Run { hz: (cycles * LANES as u64) as f64 / dt, timed_bytes, warmup_bytes }
}

/// Best of `reps` timed runs (timing noise only shrinks `hz`, so the max
/// is the honest estimate of each configuration's capability).
fn best(
    d: &Design,
    c: &Compiled,
    sparse: bool,
    wave: bool,
    warmup: u64,
    cycles: u64,
    reps: usize,
) -> Run {
    let mut b = run(d, c, sparse, wave, warmup, cycles);
    for _ in 1..reps {
        let r = run(d, c, sparse, wave, warmup, cycles);
        if r.hz > b.hz {
            b = Run { hz: r.hz, ..b };
        }
    }
    b
}

fn main() {
    let full = std::env::var("RTEAAL_FULL").map(|v| v != "0").unwrap_or(false);
    let warmup = 512u64;
    let cycles: u64 = if full { 200_000 } else { 20_000 };
    let reps = 3;

    let d = catalog("alu_farm_16").expect("catalog design");
    let c = compile_design(&d, CompileOpts::default());

    println!(
        "fig25: waveform tax on a frozen run — {} P={PARTS} B={LANES}, {cycles} timed cycles",
        d.name
    );
    let mut sparse_pair = (0.0f64, 0.0f64);
    for sparse in [false, true] {
        let off = best(&d, &c, sparse, false, warmup, cycles, reps);
        let on = best(&d, &c, sparse, true, warmup, cycles, reps);
        println!(
            "  {}: wave-off {:8.2} M lane-cyc/s | wave-on {:8.2} M lane-cyc/s \
             ({:5.1}% kept) | dump {} B, frozen tail {} B",
            if sparse { "sparse" } else { "dense " },
            off.hz / 1e6,
            on.hz / 1e6,
            100.0 * on.hz / off.hz,
            on.warmup_bytes,
            on.timed_bytes,
        );
        if sparse {
            sparse_pair = (off.hz, on.hz);
        }
        // quiescent-cost acceptance: the frozen window writes nothing —
        // holds on the dense engine too (no tracker ⇒ no mask gate, but
        // the value-diff writer still emits zero lines for zero change)
        assert_eq!(
            on.timed_bytes, 0,
            "frozen window must emit zero waveform bytes (sparse={sparse})"
        );
        assert!(on.warmup_bytes > 0, "warm-up must include the first full dump");
    }

    // throughput acceptance: ≤20% wave tax on the sparse engine
    let (off_hz, on_hz) = sparse_pair;
    assert!(
        on_hz >= 0.8 * off_hz,
        "sparse wave-on throughput ({:.2e}) must stay within 20% of wave-off ({:.2e})",
        on_hz,
        off_hz
    );
}
