//! Bench: Fig 15 + Table 4 kernel compilation cost and binary size (see coordinator::report and DESIGN.md experiment index).
//! Quick by default; set RTEAAL_FULL=1 for full-length runs.

rteaal::install_tracking_alloc!();

fn main() {
    let ctx = rteaal::coordinator::report::Ctx::from_env();
    let tables = rteaal::coordinator::report::run_experiment("fig15", &ctx).expect("known experiment");
    for t in tables {
        println!("{}", t.render());
        if let Ok(p) = t.save_csv("fig15") {
            eprintln!("csv: {}", p.display());
        }
    }
}
