//! Bench: Fig 22 lane-batched throughput sweep (ours, beyond the paper —
//! see coordinator::report). Quick by default; set RTEAAL_FULL=1 for
//! full-length runs.
//!
//! Acceptance check built in: batching must pay on the unrolled end —
//! the TI kernel's B=8 *aggregate* lane-cycles/sec must exceed its B=1
//! throughput (one tape walk amortized over 8 lanes).

rteaal::install_tracking_alloc!();

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::coordinator::sweep;
use rteaal::designs::catalog;
use rteaal::kernels::KernelConfig;

fn main() {
    let ctx = rteaal::coordinator::report::Ctx::from_env();
    let tables = rteaal::coordinator::report::run_experiment("fig22", &ctx).expect("known experiment");
    for t in tables {
        println!("{}", t.render());
        if let Ok(p) = t.save_csv("fig22") {
            eprintln!("csv: {}", p.display());
        }
    }

    // acceptance: B=8 aggregate > B=1 on the TI kernel
    let d = catalog("rocket_like_1c").expect("catalog design");
    let c = compile_design(&d, CompileOpts::default());
    let cycles = 1000;
    let b1 = sweep::measure_kernel_lanes(&d, &c, KernelConfig::TI, 1, cycles);
    let b8 = sweep::measure_kernel_lanes(&d, &c, KernelConfig::TI, 8, cycles);
    println!(
        "TI aggregate throughput: B=1 {:.2} M lane-cyc/s, B=8 {:.2} M lane-cyc/s ({:.2}x)",
        b1.hz / 1e6,
        b8.hz / 1e6,
        b8.hz / b1.hz
    );
    assert!(
        b8.hz > b1.hz,
        "B=8 aggregate throughput ({:.2e}) should exceed B=1 ({:.2e}) on TI",
        b8.hz,
        b1.hz
    );
}
