//! Cross-cutting property suite: every execution engine in the repo —
//! graph interpreter, slot-file IrSim, the Einsum cascade evaluator, all
//! seven kernels, the -O0 variant, all baselines, and the partitioned
//! simulator — must agree on random circuits and random stimulus, before
//! and after every optimization pipeline.

use rteaal::baselines::{essent_like::EssentLike, event_driven::EventDriven, verilator_like::VerilatorLike};
use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::catalog;
use rteaal::einsum::CascadeSim;
use rteaal::graph::builder::{random_circuit, random_inputs};
use rteaal::graph::passes;
use rteaal::graph::RefSim;
use rteaal::kernels::{
    build_batch, build_batch_baseline, build_sparse, build_with_oim, unopt::UnoptKernel,
    BatchKernel, KernelConfig, SimKernel, ALL_KERNELS, BATCHED_KERNELS, SPARSE_KERNELS,
};
use rteaal::tensor::ir::lower;
use rteaal::tensor::oim::Oim;
use rteaal::util::propcheck;

/// The flagship property: 13 engines, one answer.
#[test]
fn all_engines_agree_on_random_circuits() {
    propcheck::check("all-engines-agree", 14, |rng, size| {
        let g = random_circuit(rng, 20 + size * 6);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);

        let mut reference = RefSim::new(opt.clone());
        let mut cascade = CascadeSim::new(&ir);
        let mut engines: Vec<Box<dyn SimKernel>> = ALL_KERNELS
            .iter()
            .map(|&k| build_with_oim(k, &ir, &oim))
            .collect();
        engines.push(Box::new(UnoptKernel::new(&ir, &oim)));
        engines.push(Box::new(VerilatorLike::new(&ir, false)));
        engines.push(Box::new(VerilatorLike::new(&ir, true)));
        engines.push(Box::new(EssentLike::new(&ir, false)));
        engines.push(Box::new(EssentLike::new(&ir, true)));
        engines.push(Box::new(EventDriven::new(&ir)));

        for cycle in 0..8 {
            let inputs = random_inputs(rng, &reference.graph);
            reference.step(&inputs);
            cascade.step(&inputs);
            let want = reference.outputs();
            if cascade.outputs() != want {
                return Err(format!("cascade diverged at cycle {cycle}"));
            }
            for e in &mut engines {
                e.step(&inputs);
                if e.outputs() != want {
                    return Err(format!("{} diverged at cycle {cycle}", e.config_name()));
                }
            }
        }
        Ok(())
    });
}

/// Optimization pipelines preserve behaviour including register state
/// visible through outputs over long runs.
#[test]
fn optimization_pipelines_preserve_long_run_behaviour() {
    propcheck::check("passes-preserve", 10, |rng, size| {
        let g = random_circuit(rng, 30 + size * 8);
        let (fused, _) = passes::optimize(&g);
        let unfused = passes::optimize_no_fusion(&g);
        let mut a = RefSim::new(g);
        let mut b = RefSim::new(fused);
        let mut c = RefSim::new(unfused);
        for cycle in 0..32 {
            let inputs = random_inputs(rng, &a.graph);
            a.step(&inputs);
            b.step(&inputs);
            c.step(&inputs);
            if a.outputs() != b.outputs() || a.outputs() != c.outputs() {
                return Err(format!("pipelines diverged at cycle {cycle}"));
            }
        }
        Ok(())
    });
}

/// The partitioned (RepCut-style) simulator agrees with single-threaded
/// execution for any partition count, under both register-ownership
/// strategies (round-robin scatter and multilevel min-cut) — ownership
/// is a performance choice, never a semantic one, even on random
/// circuits.
#[test]
fn partitioned_simulation_agrees() {
    use rteaal::partition::PartitionerKind;
    propcheck::check("partitioned-agrees", 8, |rng, size| {
        let g = random_circuit(rng, 40 + size * 8);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let n = 2 + rng.index(3);
        let kind = if rng.index(2) == 0 {
            PartitionerKind::RoundRobin
        } else {
            PartitionerKind::MinCut
        };
        let mut par = rteaal::coordinator::parallel::ParallelSim::with_partitioner(
            &ir,
            rteaal::kernels::KernelConfig::TI,
            n,
            kind,
        );
        let mut single = build_with_oim(rteaal::kernels::KernelConfig::TI, &ir, &oim);
        for cycle in 0..12 {
            let inputs = random_inputs(rng, &opt);
            single.step(&inputs);
            par.step(&inputs);
            if par.outputs() != single.outputs() {
                return Err(format!(
                    "partitioned ({n}, {}) diverged at cycle {cycle}",
                    kind.name()
                ));
            }
        }
        Ok(())
    });
}

/// FIRRTL print→parse→compile→simulate round trip through the whole
/// front half of the pipeline.
#[test]
fn firrtl_roundtrip_through_kernels() {
    propcheck::check("firrtl-roundtrip-kernels", 8, |rng, size| {
        let g = random_circuit(rng, 20 + size * 5);
        let text = rteaal::firrtl::print(&g);
        let g2 = rteaal::firrtl::parse(&text).map_err(|e| e.to_string())?;
        let ir = lower(&g2);
        let oim = Oim::from_ir(&ir);
        let mut reference = RefSim::new(g);
        let mut kernel = build_with_oim(rteaal::kernels::KernelConfig::PSU, &ir, &oim);
        for cycle in 0..8 {
            let inputs = random_inputs(rng, &reference.graph);
            reference.step(&inputs);
            kernel.step(&inputs);
            if kernel.outputs() != reference.outputs() {
                return Err(format!("roundtrip kernel diverged at cycle {cycle}"));
            }
        }
        Ok(())
    });
}

/// The differential batching property: a `B`-lane batched run is
/// bit-identical to `B` independent single-lane runs of the corresponding
/// scalar kernel, for every batched kernel — since the batched IU/SU
/// executors landed, all seven binding levels — and `B ∈ {1, 3, 8}`:
/// lanes share one OIM walk / tape but must never interact.
#[test]
fn batched_kernels_match_sequential_lanes() {
    propcheck::check("batched-vs-sequential", 6, |rng, size| {
        let g = random_circuit(rng, 15 + size * 4);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let mut out_buf: Vec<(String, u64)> = Vec::new();
        for &lanes in &[1usize, 3, 8] {
            for cfg in BATCHED_KERNELS {
                let mut batched = build_batch(cfg, &ir, &oim, lanes);
                let mut singles: Vec<Box<dyn SimKernel>> =
                    (0..lanes).map(|_| build_with_oim(cfg, &ir, &oim)).collect();
                for cycle in 0..5 {
                    let per_lane: Vec<Vec<u64>> =
                        (0..lanes).map(|_| random_inputs(rng, &opt)).collect();
                    let mut flat = vec![0u64; opt.inputs.len() * lanes];
                    for (l, inp) in per_lane.iter().enumerate() {
                        for (i, &v) in inp.iter().enumerate() {
                            flat[i * lanes + l] = v;
                        }
                    }
                    batched.step(&flat);
                    for (l, s) in singles.iter_mut().enumerate() {
                        s.step(&per_lane[l]);
                        batched.write_lane_outputs(l, &mut out_buf);
                        if out_buf != s.outputs() {
                            return Err(format!(
                                "{} lane {l}/{lanes} diverged at cycle {cycle}",
                                cfg.name()
                            ));
                        }
                    }
                }
                // the full lane-major slot files must agree too, not just
                // the named outputs
                let want: Vec<u64> = {
                    let mut v = vec![0u64; ir.num_slots * lanes];
                    for (l, s) in singles.iter().enumerate() {
                        for (slot, &val) in s.slots().iter().enumerate() {
                            v[slot * lanes + l] = val;
                        }
                    }
                    v
                };
                if batched.slots() != &want[..] {
                    return Err(format!("{} lane-major slot file diverged", cfg.name()));
                }
            }
        }
        Ok(())
    });
}

/// The tiling differential property: the explicit `[u64; 8]`-tile
/// executors are bit-identical to the retained pre-tile lane-at-a-time
/// baselines ([`build_batch_baseline`]) for every batched kernel, across
/// batch widths chosen to exercise every remainder decomposition —
/// `B ∈ {1, 3, 7, 9, 63, 64}` covers scalar-only (1, 3), one 4-wide step
/// plus scalar (7), one 8-wide tile plus scalar (9), the worst case
/// 8-wide × 7 + 4-wide + 3 scalar (63), and the exact-tile path (64).
/// Both the named outputs and the full lane-major slot file must agree.
#[test]
fn tiled_kernels_match_scalar_baseline_across_remainder_widths() {
    propcheck::check("tiled-vs-scalar", 6, |rng, size| {
        let g = random_circuit(rng, 15 + size * 4);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let mut tiled_buf: Vec<(String, u64)> = Vec::new();
        let mut scalar_buf: Vec<(String, u64)> = Vec::new();
        for &lanes in &[1usize, 3, 7, 9, 63, 64] {
            for cfg in BATCHED_KERNELS {
                let mut tiled = build_batch(cfg, &ir, &oim, lanes);
                let mut scalar = build_batch_baseline(cfg, &ir, &oim, lanes);
                for cycle in 0..4 {
                    let mut flat = vec![0u64; opt.inputs.len() * lanes];
                    for l in 0..lanes {
                        for (i, &v) in random_inputs(rng, &opt).iter().enumerate() {
                            flat[i * lanes + l] = v;
                        }
                    }
                    tiled.step(&flat);
                    scalar.step(&flat);
                    if tiled.slots() != scalar.slots() {
                        return Err(format!(
                            "{} tiled slot file diverged from baseline (B {lanes}, cycle {cycle})",
                            cfg.name()
                        ));
                    }
                    for l in [0, lanes - 1] {
                        tiled.write_lane_outputs(l, &mut tiled_buf);
                        scalar.write_lane_outputs(l, &mut scalar_buf);
                        if tiled_buf != scalar_buf {
                            return Err(format!(
                                "{} tiled lane {l} outputs diverged from baseline (B {lanes}, cycle {cycle})",
                                cfg.name()
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Tiling composes with thread-level partitioning: a partitioned tiled
/// run is bit-identical to the partitioned pre-tile baseline
/// ([`BatchParallelSim::with_partitioner_baseline`]) at `P ∈ {2, 4}`,
/// including remainder-heavy batch widths — outputs for every lane and
/// every committed register.
#[test]
fn partitioned_tiled_matches_partitioned_baseline() {
    use rteaal::coordinator::parallel::BatchParallelSim;
    use rteaal::partition::PartitionerKind;
    propcheck::check("partitioned-tiled-vs-scalar", 5, |rng, size| {
        let g = random_circuit(rng, 30 + size * 6);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let mut tiled_buf: Vec<(String, u64)> = Vec::new();
        let mut scalar_buf: Vec<(String, u64)> = Vec::new();
        for &(parts, lanes) in &[(2usize, 3usize), (2, 8), (4, 7), (4, 8)] {
            for cfg in [KernelConfig::NU, KernelConfig::TI] {
                let mut tiled = BatchParallelSim::with_partitioner(
                    &ir,
                    cfg,
                    parts,
                    lanes,
                    false,
                    PartitionerKind::MinCut,
                );
                let mut scalar = BatchParallelSim::with_partitioner_baseline(
                    &ir,
                    cfg,
                    parts,
                    lanes,
                    PartitionerKind::MinCut,
                );
                for cycle in 0..5 {
                    let mut flat = vec![0u64; opt.inputs.len() * lanes];
                    for l in 0..lanes {
                        for (i, &v) in random_inputs(rng, &opt).iter().enumerate() {
                            flat[i * lanes + l] = v;
                        }
                    }
                    tiled.step(&flat);
                    scalar.step(&flat);
                    for l in 0..lanes {
                        tiled.write_lane_outputs(l, &mut tiled_buf);
                        scalar.write_lane_outputs(l, &mut scalar_buf);
                        if tiled_buf != scalar_buf {
                            return Err(format!(
                                "{} P{parts}xB{lanes} tiled lane {l} diverged at cycle {cycle}",
                                cfg.name()
                            ));
                        }
                    }
                    for &(reg, _, _) in &ir.commits {
                        for l in 0..lanes {
                            if tiled.reg_lane(reg, l) != scalar.reg_lane(reg, l) {
                                return Err(format!(
                                    "{} P{parts}xB{lanes} reg {reg} lane {l} diverged at cycle {cycle}",
                                    cfg.name()
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Divergent-lane initialization property: pre-run `poke_lane`s — the
/// mechanism behind `Design::lane_init` — keep every batched kernel
/// (including the IU and SU executors) bit-identical to scalar kernels
/// given the same per-lane register pokes: outputs *and* the full
/// lane-major slot file, over multiple cycles of decorrelated stimulus.
#[test]
fn batched_poke_lane_matches_scalar_pokes() {
    propcheck::check("batched-poke-lane", 6, |rng, size| {
        let g = random_circuit(rng, 15 + size * 4);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        if ir.commits.is_empty() {
            return Ok(()); // no register state to diverge
        }
        let lanes = 4usize;
        for cfg in BATCHED_KERNELS {
            let mut batched = build_batch(cfg, &ir, &oim, lanes);
            let mut singles: Vec<Box<dyn SimKernel>> =
                (0..lanes).map(|_| build_with_oim(cfg, &ir, &oim)).collect();
            // divergent init: give every register a different value per lane
            for &(reg, _, m) in &ir.commits {
                for (l, s) in singles.iter_mut().enumerate() {
                    let val = rng.bits(64) & m;
                    batched.poke_lane(reg, l, val);
                    s.poke(reg, val);
                }
            }
            for cycle in 0..4 {
                let per_lane: Vec<Vec<u64>> =
                    (0..lanes).map(|_| random_inputs(rng, &opt)).collect();
                let mut flat = vec![0u64; opt.inputs.len() * lanes];
                for (l, inp) in per_lane.iter().enumerate() {
                    for (i, &v) in inp.iter().enumerate() {
                        flat[i * lanes + l] = v;
                    }
                }
                batched.step(&flat);
                for (l, s) in singles.iter_mut().enumerate() {
                    s.step(&per_lane[l]);
                    if batched.lane_outputs(l) != s.outputs() {
                        return Err(format!(
                            "{} lane {l} diverged after pokes at cycle {cycle}",
                            cfg.name()
                        ));
                    }
                }
            }
            for (l, s) in singles.iter().enumerate() {
                for (slot, &val) in s.slots().iter().enumerate() {
                    if batched.slots()[slot * lanes + l] != val {
                        return Err(format!(
                            "{} slot {slot} lane {l} diverged after pokes",
                            cfg.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The sparsity correctness property: every sparse (activity-masked)
/// batched kernel is **bit-identical** — named outputs *and* the full
/// lane-major slot file — to its dense batched counterpart on random
/// circuits, across toggle rates {0.0, 0.05, 0.5, 1.0} and
/// `B ∈ {1, 3, 7, 9, 63, 64}` (the full remainder-decomposition grid:
/// the sparse executors' full-mask fast path takes the tiled loop while
/// partial masks bit-iterate, and both must land on identical bits).
/// Skipping must be invisible: a (group, lane) is only
/// skipped when recomputation would reproduce the very same values.
#[test]
fn sparse_batched_is_bit_identical_to_dense_batched() {
    propcheck::check("sparse-vs-dense", 6, |rng, size| {
        let g = random_circuit(rng, 15 + size * 4);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let n_inputs = opt.inputs.len();
        let widths: Vec<u8> = opt.inputs.iter().map(|p| p.width).collect();
        let mut sparse_buf: Vec<(String, u64)> = Vec::new();
        let mut dense_buf: Vec<(String, u64)> = Vec::new();
        for &rate in &[0.0f64, 0.05, 0.5, 1.0] {
            for &lanes in &[1usize, 3, 7, 9, 63, 64] {
                for cfg in SPARSE_KERNELS {
                    let mut dense = build_batch(cfg, &ir, &oim, lanes);
                    let mut sparse = build_sparse(cfg, &ir, &oim, lanes);
                    // toggle-rate-controlled lane-major stimulus: draw on
                    // cycle 0, then each lane changes (every port XORed
                    // with a nonzero delta) with probability `rate`
                    let mut held = vec![0u64; n_inputs * lanes];
                    for cycle in 0..6 {
                        for l in 0..lanes {
                            if cycle == 0 {
                                for (i, &w) in widths.iter().enumerate() {
                                    held[i * lanes + l] = rng.bits(w);
                                }
                            } else if rng.chance(rate) {
                                for (i, &w) in widths.iter().enumerate() {
                                    held[i * lanes + l] ^= rng.bits(w) | 1;
                                }
                            }
                        }
                        dense.step(&held);
                        sparse.step(&held);
                        if sparse.slots() != dense.slots() {
                            return Err(format!(
                                "{} sparse slot file diverged (rate {rate}, B {lanes}, cycle {cycle})",
                                cfg.name()
                            ));
                        }
                        for l in [0, lanes - 1] {
                            sparse.write_lane_outputs(l, &mut sparse_buf);
                            dense.write_lane_outputs(l, &mut dense_buf);
                            if sparse_buf != dense_buf {
                                return Err(format!(
                                    "{} sparse lane {l} outputs diverged (rate {rate}, B {lanes}, cycle {cycle})",
                                    cfg.name()
                                ));
                            }
                        }
                    }
                    let stats = sparse
                        .activity_stats()
                        .ok_or_else(|| "sparse kernel reports no activity stats".to_string())?;
                    if stats.evaluated_op_lanes > stats.total_op_lanes {
                        return Err("evaluated op-lanes exceed total".into());
                    }
                }
            }
        }
        Ok(())
    });
}

/// The targeted-invalidation property: out-of-band `poke_lane` writes no
/// longer recold the sparse executors, yet sparse stays **bit-identical**
/// to dense under random mid-run pokes of random register slots and
/// lanes — over *frozen* stimulus, so the pokes are the only activity and
/// a dropped invalidation edge cannot hide behind input-driven
/// re-evaluation.
#[test]
fn sparse_poke_lane_targeted_invalidation_matches_dense() {
    propcheck::check("sparse-poke-targeted", 6, |rng, size| {
        let g = random_circuit(rng, 15 + size * 4);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        if ir.commits.is_empty() {
            return Ok(()); // no register state to poke
        }
        let lanes = 8usize;
        let widths: Vec<u8> = opt.inputs.iter().map(|p| p.width).collect();
        let mut held = vec![0u64; opt.inputs.len() * lanes];
        for l in 0..lanes {
            for (i, &w) in widths.iter().enumerate() {
                held[i * lanes + l] = rng.bits(w);
            }
        }
        for cfg in SPARSE_KERNELS {
            let mut dense = build_batch(cfg, &ir, &oim, lanes);
            let mut sparse = build_sparse(cfg, &ir, &oim, lanes);
            for cycle in 0..8 {
                if cycle % 2 == 1 {
                    let (reg, _, m) = ir.commits[rng.index(ir.commits.len())];
                    let lane = rng.index(lanes);
                    let val = rng.bits(64) & m;
                    dense.poke_lane(reg, lane, val);
                    sparse.poke_lane(reg, lane, val);
                }
                dense.step(&held);
                sparse.step(&held);
                if sparse.slots() != dense.slots() {
                    return Err(format!(
                        "{} slot files diverged after mid-run pokes at cycle {cycle}",
                        cfg.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// The composed-sparsity property on random circuits: a sparse
/// partitioned run (group-masked kernels inside partitions, targeted RUM
/// feed, partition-level skipping) is bit-identical to a dense
/// partitioned run — outputs and every committed register — including
/// across a mid-run poke, for random partition counts.
#[test]
fn sparse_partitioned_matches_dense_partitioned_on_random_circuits() {
    use rteaal::coordinator::parallel::BatchParallelSim;
    propcheck::check("sparse-partitioned", 6, |rng, size| {
        let g = random_circuit(rng, 30 + size * 6);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let lanes = 4usize;
        let n = 2 + rng.index(3);
        let mut dense = BatchParallelSim::new(&ir, KernelConfig::TI, n, lanes, false);
        let mut sparse = BatchParallelSim::new(&ir, KernelConfig::TI, n, lanes, true);
        let mut dense_buf: Vec<(String, u64)> = Vec::new();
        let mut sparse_buf: Vec<(String, u64)> = Vec::new();
        for cycle in 0..10 {
            if cycle == 3 && !ir.commits.is_empty() {
                let (reg, _, m) = ir.commits[rng.index(ir.commits.len())];
                let lane = rng.index(lanes);
                let val = rng.bits(64) & m;
                dense.poke_lane(reg, lane, val);
                sparse.poke_lane(reg, lane, val);
            }
            let per_lane: Vec<Vec<u64>> = (0..lanes).map(|_| random_inputs(rng, &opt)).collect();
            let mut flat = vec![0u64; opt.inputs.len() * lanes];
            for (l, inp) in per_lane.iter().enumerate() {
                for (i, &v) in inp.iter().enumerate() {
                    flat[i * lanes + l] = v;
                }
            }
            dense.step(&flat);
            sparse.step(&flat);
            for l in 0..lanes {
                dense.write_lane_outputs(l, &mut dense_buf);
                sparse.write_lane_outputs(l, &mut sparse_buf);
                if dense_buf != sparse_buf {
                    return Err(format!(
                        "sparse partitioned (n={n}) lane {l} diverged at cycle {cycle}"
                    ));
                }
            }
            for &(reg, _, _) in &ir.commits {
                for l in 0..lanes {
                    if sparse.reg_lane(reg, l) != dense.reg_lane(reg, l) {
                        return Err(format!(
                            "sparse partitioned (n={n}) reg {reg} lane {l} diverged at cycle {cycle}"
                        ));
                    }
                }
            }
        }
        if sparse.group_stats().is_none() {
            return Err("sparse TI partitioned run must report group-level stats".into());
        }
        Ok(())
    });
}

/// Skip-rate bounds on designs with deterministic activity. Idle half:
/// `fir8` with frozen inputs (toggle rate 0.0) goes quiescent once the
/// delay line drains, so a substantial fraction of the op-lane work must
/// be skipped. Saturated half: `alu32` at toggle rate 1.0 — every group
/// transitively depends only on the inputs (its result register is a
/// write-only sink, never read back), and every lane's inputs are forced
/// to change every cycle, so the skip-rate must be **exactly zero**.
#[test]
fn sparse_skip_rate_is_positive_idle_and_zero_saturated() {
    let lanes = 8usize;
    let cycles = 64u64;
    for cfg in SPARSE_KERNELS {
        // idle: inputs freeze after cycle 0 → whole cycles go quiescent
        let d = catalog("fir8").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        let mut k = build_sparse(cfg, &c.ir, &c.oim, lanes);
        let mut stim = d.make_lane_stimulus_toggle(lanes, 0.0);
        for cyc in 0..cycles {
            k.step(&stim(cyc));
        }
        let idle = k.activity_stats().unwrap();
        assert!(
            idle.skip_rate() > 0.5,
            "{}: idle run skipped only {:.1}% of op-lanes",
            cfg.name(),
            100.0 * idle.skip_rate()
        );

        // saturated: every lane's inputs forced to change every cycle
        let d = catalog("alu32").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        let mut k = build_sparse(cfg, &c.ir, &c.oim, lanes);
        let mut stim = d.make_lane_stimulus_toggle(lanes, 1.0);
        for cyc in 0..cycles {
            k.step(&stim(cyc));
        }
        let hot = k.activity_stats().unwrap();
        assert_eq!(
            hot.evaluated_op_lanes,
            hot.total_op_lanes,
            "{}: saturated run must have skip-rate exactly 0 (got {:.3})",
            cfg.name(),
            hot.skip_rate()
        );
    }
}

/// OIM serialization is array-exact: export → JSON → re-import preserves
/// the format-B arrays and the re-derived format-C arrays bit for bit,
/// and kernels built from the re-imported OIM still agree with the graph
/// reference interpreter; the dense tensor export round-trips through its
/// JSON too.
#[test]
fn oim_serialization_roundtrip_is_exact() {
    propcheck::check("oim-serialization", 8, |rng, size| {
        let g = random_circuit(rng, 20 + size * 5);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let json = oim.to_json().to_string();
        let oim2 = Oim::from_json(&rteaal::util::json::parse(&json).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        if oim.b != oim2.b {
            return Err("re-imported format-B arrays differ".into());
        }
        if oim.c != oim2.c || oim.n_payload != oim2.n_payload {
            return Err("re-derived format-C arrays differ".into());
        }
        if oim.i_payload != oim2.i_payload || oim.num_slots != oim2.num_slots {
            return Err("re-imported shapes differ".into());
        }

        let mut reference = RefSim::new(opt.clone());
        let mut kernels: Vec<Box<dyn SimKernel>> =
            [KernelConfig::RU, KernelConfig::PSU, KernelConfig::TI]
                .iter()
                .map(|&k| build_with_oim(k, &ir, &oim2))
                .collect();
        for cycle in 0..6 {
            let inputs = random_inputs(rng, &reference.graph);
            reference.step(&inputs);
            let want = reference.outputs();
            for k in &mut kernels {
                k.step(&inputs);
                if k.outputs() != want {
                    return Err(format!(
                        "{} from re-imported OIM diverged at cycle {cycle}",
                        k.config_name()
                    ));
                }
            }
        }

        // dense export (u32-only, unfused) JSON round trip
        let unfused = passes::optimize_no_fusion(&g);
        let uir = lower(&unfused);
        if uir.slot_widths.iter().all(|&w| w <= 32) {
            let dense =
                rteaal::tensor::export::to_dense(&uir, 16).map_err(|e| e.to_string())?;
            let dj = rteaal::util::json::parse(&dense.to_json().to_string())
                .map_err(|e| e.to_string())?;
            let dense2 = rteaal::tensor::export::DenseDesign::from_json(&dj)
                .map_err(|e| e.to_string())?;
            if dense != dense2 {
                return Err("dense export JSON round trip differs".into());
            }
        }
        Ok(())
    });
}

/// OIM JSON round trip preserves kernel behaviour (the paper's runtime
/// flow: OIM is stored as JSON and loaded at simulation time, §6.1).
#[test]
fn oim_json_roundtrip_preserves_behaviour() {
    propcheck::check("oim-json-kernels", 8, |rng, size| {
        let g = random_circuit(rng, 20 + size * 5);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let json = oim.to_json().to_string();
        let oim2 = Oim::from_json(&rteaal::util::json::parse(&json).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        let mut a = build_with_oim(rteaal::kernels::KernelConfig::NU, &ir, &oim);
        let mut b = build_with_oim(rteaal::kernels::KernelConfig::NU, &ir, &oim2);
        for _ in 0..8 {
            let inputs = random_inputs(rng, &opt);
            a.step(&inputs);
            b.step(&inputs);
            if a.outputs() != b.outputs() {
                return Err("json-roundtripped OIM diverged".into());
            }
        }
        Ok(())
    });
}
