//! Cross-cutting property suite: every execution engine in the repo —
//! graph interpreter, slot-file IrSim, the Einsum cascade evaluator, all
//! seven kernels, the -O0 variant, all baselines, and the partitioned
//! simulator — must agree on random circuits and random stimulus, before
//! and after every optimization pipeline.

use rteaal::baselines::{essent_like::EssentLike, event_driven::EventDriven, verilator_like::VerilatorLike};
use rteaal::einsum::CascadeSim;
use rteaal::graph::builder::{random_circuit, random_inputs};
use rteaal::graph::passes;
use rteaal::graph::RefSim;
use rteaal::kernels::{build_with_oim, unopt::UnoptKernel, SimKernel, ALL_KERNELS};
use rteaal::tensor::ir::lower;
use rteaal::tensor::oim::Oim;
use rteaal::util::propcheck;

/// The flagship property: 13 engines, one answer.
#[test]
fn all_engines_agree_on_random_circuits() {
    propcheck::check("all-engines-agree", 14, |rng, size| {
        let g = random_circuit(rng, 20 + size * 6);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);

        let mut reference = RefSim::new(opt.clone());
        let mut cascade = CascadeSim::new(&ir);
        let mut engines: Vec<Box<dyn SimKernel>> = ALL_KERNELS
            .iter()
            .map(|&k| build_with_oim(k, &ir, &oim))
            .collect();
        engines.push(Box::new(UnoptKernel::new(&ir, &oim)));
        engines.push(Box::new(VerilatorLike::new(&ir, false)));
        engines.push(Box::new(VerilatorLike::new(&ir, true)));
        engines.push(Box::new(EssentLike::new(&ir, false)));
        engines.push(Box::new(EssentLike::new(&ir, true)));
        engines.push(Box::new(EventDriven::new(&ir)));

        for cycle in 0..8 {
            let inputs = random_inputs(rng, &reference.graph);
            reference.step(&inputs);
            cascade.step(&inputs);
            let want = reference.outputs();
            if cascade.outputs() != want {
                return Err(format!("cascade diverged at cycle {cycle}"));
            }
            for e in &mut engines {
                e.step(&inputs);
                if e.outputs() != want {
                    return Err(format!("{} diverged at cycle {cycle}", e.config_name()));
                }
            }
        }
        Ok(())
    });
}

/// Optimization pipelines preserve behaviour including register state
/// visible through outputs over long runs.
#[test]
fn optimization_pipelines_preserve_long_run_behaviour() {
    propcheck::check("passes-preserve", 10, |rng, size| {
        let g = random_circuit(rng, 30 + size * 8);
        let (fused, _) = passes::optimize(&g);
        let unfused = passes::optimize_no_fusion(&g);
        let mut a = RefSim::new(g);
        let mut b = RefSim::new(fused);
        let mut c = RefSim::new(unfused);
        for cycle in 0..32 {
            let inputs = random_inputs(rng, &a.graph);
            a.step(&inputs);
            b.step(&inputs);
            c.step(&inputs);
            if a.outputs() != b.outputs() || a.outputs() != c.outputs() {
                return Err(format!("pipelines diverged at cycle {cycle}"));
            }
        }
        Ok(())
    });
}

/// The partitioned (RepCut-style) simulator agrees with single-threaded
/// execution for any partition count.
#[test]
fn partitioned_simulation_agrees() {
    propcheck::check("partitioned-agrees", 8, |rng, size| {
        let g = random_circuit(rng, 40 + size * 8);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let n = 2 + rng.index(3);
        let mut par =
            rteaal::coordinator::parallel::ParallelSim::new(&ir, rteaal::kernels::KernelConfig::TI, n);
        let mut single = build_with_oim(rteaal::kernels::KernelConfig::TI, &ir, &oim);
        for cycle in 0..12 {
            let inputs = random_inputs(rng, &opt);
            single.step(&inputs);
            par.step(&inputs);
            if par.outputs() != single.outputs() {
                return Err(format!("partitioned ({n}) diverged at cycle {cycle}"));
            }
        }
        Ok(())
    });
}

/// FIRRTL print→parse→compile→simulate round trip through the whole
/// front half of the pipeline.
#[test]
fn firrtl_roundtrip_through_kernels() {
    propcheck::check("firrtl-roundtrip-kernels", 8, |rng, size| {
        let g = random_circuit(rng, 20 + size * 5);
        let text = rteaal::firrtl::print(&g);
        let g2 = rteaal::firrtl::parse(&text).map_err(|e| e.to_string())?;
        let ir = lower(&g2);
        let oim = Oim::from_ir(&ir);
        let mut reference = RefSim::new(g);
        let mut kernel = build_with_oim(rteaal::kernels::KernelConfig::PSU, &ir, &oim);
        for cycle in 0..8 {
            let inputs = random_inputs(rng, &reference.graph);
            reference.step(&inputs);
            kernel.step(&inputs);
            if kernel.outputs() != reference.outputs() {
                return Err(format!("roundtrip kernel diverged at cycle {cycle}"));
            }
        }
        Ok(())
    });
}

/// OIM JSON round trip preserves kernel behaviour (the paper's runtime
/// flow: OIM is stored as JSON and loaded at simulation time, §6.1).
#[test]
fn oim_json_roundtrip_preserves_behaviour() {
    propcheck::check("oim-json-kernels", 8, |rng, size| {
        let g = random_circuit(rng, 20 + size * 5);
        let (opt, _) = passes::optimize(&g);
        let ir = lower(&opt);
        let oim = Oim::from_ir(&ir);
        let json = oim.to_json().to_string();
        let oim2 = Oim::from_json(&rteaal::util::json::parse(&json).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        let mut a = build_with_oim(rteaal::kernels::KernelConfig::NU, &ir, &oim);
        let mut b = build_with_oim(rteaal::kernels::KernelConfig::NU, &ir, &oim2);
        for _ in 0..8 {
            let inputs = random_inputs(rng, &opt);
            a.step(&inputs);
            b.step(&inputs);
            if a.outputs() != b.outputs() {
                return Err("json-roundtripped OIM diverged".into());
            }
        }
        Ok(())
    });
}
