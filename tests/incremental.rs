//! Incremental compilation end-to-end.
//!
//! Three guarantees, matching the acceptance criteria of the cone-delta
//! reuse path:
//! * **splice oracle** — the delta pass's spliced OIM and GDG must be
//!   *equal* to from-scratch rebuilds over the same grafted IR (the
//!   splices are pure reuse, never approximations);
//! * **bit identity** — a simulator built from the incrementally opened
//!   artifacts must match a cold-compiled one on every output *and*
//!   every committed register (compared by register name — edits
//!   renumber slots) on every cycle, across P ∈ {1, 4} × B ∈ {1, 8} ×
//!   dense/sparse;
//! * **speed** — the warm open of a one-module edit of `rocket_like_1c`
//!   must cost less than half of a from-scratch open.

use std::collections::HashMap;

use rteaal::activity::gdg::GroupDepGraph;
use rteaal::coordinator::incremental::delta_compile;
use rteaal::coordinator::parallel::BatchParallelSim;
use rteaal::designs::catalog;
use rteaal::kernels::KernelConfig;
use rteaal::partition::PartitionerKind;
use rteaal::service::cache::DesignCache;
use rteaal::tensor::ir::LayerIr;
use rteaal::tensor::oim::Oim;

/// (register name, register slot) for every commit; every commit slot
/// carries the register's name (set by `Graph::reg` and kept by `lower`).
fn named_commits(ir: &LayerIr) -> Vec<(String, u32)> {
    ir.commits
        .iter()
        .map(|&(slot, _, _)| {
            let name = ir.slot_names[slot as usize].as_deref().expect("commit slot is named");
            (name.to_string(), slot)
        })
        .collect()
}

#[test]
fn delta_artifacts_match_a_from_scratch_rebuild_of_the_grafted_ir() {
    let base = catalog("fir8").expect("catalog design");
    let edited = catalog("fir8_edit").expect("catalog edit variant");
    let mut cache = DesignCache::new(None, 4);
    let (donor, _) = cache.open_design(&base, true, 2, PartitionerKind::MinCut).expect("open");
    let delta = delta_compile(&edited, &donor, true).expect("same-family edit must delta");
    assert!(!delta.changed_regs.is_empty(), "the edit changes at least one cone");
    assert!(delta.reused_groups > 0, "untouched layers keep their groups");
    let oim = Oim::from_ir(&delta.ir);
    assert_eq!(delta.oim, oim, "spliced OIM must equal a from-scratch rebuild");
    let gdg = GroupDepGraph::build(&delta.ir, &oim);
    assert_eq!(delta.gdg, gdg, "spliced GDG must equal a from-scratch rebuild");
}

#[test]
fn incremental_simulator_is_bit_identical_to_cold_across_configs() {
    let base = catalog("rocket_like_1c").expect("catalog design");
    let edited = catalog("rocket_like_1c_edit").expect("catalog edit variant");
    let pk = PartitionerKind::MinCut;
    let cycles = 50u64;
    for &parts in &[1usize, 4] {
        let mut cold_cache = DesignCache::new(None, 4);
        let (cold, rc) = cold_cache.open_design(&edited, true, parts, pk).expect("cold open");
        let mut warm_cache = DesignCache::new(None, 4);
        warm_cache.open_design(&base, true, parts, pk).expect("base open");
        let (inc, ri) =
            warm_cache.open_design_incremental(&edited, true, parts, pk).expect("warm open");
        assert!(ri.incremental, "P={parts}: the edit must take the delta path");
        assert_eq!(rc.key, ri.key, "both opens commit under the same content key");
        let cold_regs = named_commits(&cold.ir);
        let inc_by_name: HashMap<String, u32> = named_commits(&inc.ir).into_iter().collect();
        assert_eq!(cold_regs.len(), inc_by_name.len(), "same register set");
        for &lanes in &[1usize, 8] {
            for &sparse in &[false, true] {
                let cfg = KernelConfig::PSU;
                let mut a = BatchParallelSim::with_partitioning(
                    &cold.ir,
                    cfg,
                    cold.partitioning(),
                    lanes,
                    sparse,
                    pk,
                );
                let mut b = BatchParallelSim::with_partitioning(
                    &inc.ir,
                    cfg,
                    inc.partitioning(),
                    lanes,
                    sparse,
                    pk,
                );
                for (slot, lane, v) in cold.resolved_lane_init(&edited, lanes).expect("init") {
                    a.poke_lane(slot, lane, v);
                }
                for (slot, lane, v) in inc.resolved_lane_init(&edited, lanes).expect("init") {
                    b.poke_lane(slot, lane, v);
                }
                let mut stim_a = edited.make_lane_stimulus(lanes);
                let mut stim_b = edited.make_lane_stimulus(lanes);
                for c in 0..cycles {
                    let frame = stim_a(c);
                    assert_eq!(frame, stim_b(c), "stimulus is deterministic");
                    a.step(&frame);
                    b.step(&frame);
                    for l in 0..lanes {
                        assert_eq!(
                            a.lane_outputs(l),
                            b.lane_outputs(l),
                            "P={parts} B={lanes} sparse={sparse} cycle {c} lane {l}: outputs"
                        );
                        for (name, slot) in &cold_regs {
                            let want = a.reg_lane(*slot, l);
                            let got = b.reg_lane(inc_by_name[name], l);
                            assert_eq!(
                                want, got,
                                "P={parts} B={lanes} sparse={sparse} cycle {c} lane {l}: \
                                 register {name}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn incremental_recompile_is_under_half_of_cold_on_rocket_like_1c() {
    let base = catalog("rocket_like_1c").expect("catalog design");
    let edited = catalog("rocket_like_1c_edit").expect("catalog edit variant");
    let (parts, pk) = (2usize, PartitionerKind::MinCut);
    // best-of-2 on both sides to absorb shared-runner noise; memory-only
    // caches so the comparison is compile work, not disk IO
    let mut cold = std::time::Duration::MAX;
    for _ in 0..2 {
        let mut cache = DesignCache::new(None, 4);
        let t0 = std::time::Instant::now();
        cache.open_design(&edited, true, parts, pk).expect("cold open");
        cold = cold.min(t0.elapsed());
    }
    let mut inc = std::time::Duration::MAX;
    for _ in 0..2 {
        let mut cache = DesignCache::new(None, 4);
        cache.open_design(&base, true, parts, pk).expect("base open");
        let t0 = std::time::Instant::now();
        let (_, r) = cache.open_design_incremental(&edited, true, parts, pk).expect("warm open");
        assert!(r.incremental, "the edit must take the delta path");
        inc = inc.min(t0.elapsed());
    }
    assert!(
        inc.as_secs_f64() < 0.5 * cold.as_secs_f64(),
        "incremental open ({:.4}s) must be under half of cold ({:.4}s)",
        inc.as_secs_f64(),
        cold.as_secs_f64()
    );
}
