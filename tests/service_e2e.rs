//! End-to-end tests for the simulation service (`rust/src/service/`):
//! the checkpoint/restore matrix from the issue (P ∈ {1,4} × B ∈ {1,8},
//! dense + sparse, `fir8` / `tiny_cpu_divergent`), packed lane-slice
//! snapshots, corrupted-snapshot rejection, and the warm-open budget
//! (warm `open_design` must cost < 10% of the cold compile).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use rteaal::designs::catalog;
use rteaal::kernels::KernelConfig;
use rteaal::partition::PartitionerKind;
use rteaal::service::cache::{DesignCache, OpenSource};
use rteaal::service::session::{SessionConfig, SessionManager};

/// Per-test scratch directory (same convention as the unit tests:
/// `std::env::temp_dir()` + pid, recreated fresh).
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rteaal_svc_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A deadline far enough out that only a wedged host could miss it.
fn far() -> Instant {
    Instant::now() + Duration::from_secs(300)
}

fn cfg(design: &str, parts: usize, lanes: usize, width: usize, sparse: bool) -> SessionConfig {
    SessionConfig {
        design: design.into(),
        kernel: KernelConfig::PSU,
        parts,
        lanes,
        width,
        sparse,
        fuse: true,
        partitioner: PartitionerKind::MinCut,
        incremental: false,
    }
}

/// One cell of the checkpoint matrix: run 30 cycles, checkpoint, run 20
/// more recording outputs, restore the checkpoint into a fresh session,
/// run the same 20 — the restored run must be bit-identical in every
/// per-cycle output record, every committed register slot of every
/// lane, and (whole-host snapshots) the full exported kernel state.
fn checkpoint_matrix_case(
    mgr: &mut SessionManager,
    dir: &std::path::Path,
    design: &str,
    parts: usize,
    lanes: usize,
    sparse: bool,
) {
    let tag = format!("{design} P={parts} B={lanes} sparse={sparse}");
    let a = mgr.open(&cfg(design, parts, lanes, lanes, sparse)).unwrap();
    mgr.submit_design(a.session, 30).unwrap();
    let warm = mgr.poll(a.session, usize::MAX, far()).unwrap();
    assert!(warm.done, "{tag}: warm-up did not finish");
    assert_eq!(warm.cycle, 30, "{tag}");

    let path = dir.join(format!("{design}_p{parts}_b{lanes}_s{}.rtal", u8::from(sparse)));
    let (bytes, at) = mgr.checkpoint(a.session, &path).unwrap();
    assert!(bytes > 0, "{tag}: empty snapshot");
    assert_eq!(at, 30, "{tag}: snapshot cycle");

    mgr.submit_design(a.session, 20).unwrap();
    let cont_a = mgr.poll(a.session, usize::MAX, far()).unwrap();
    assert!(cont_a.done && cont_a.cycle == 50, "{tag}");

    let (b, restored_cycle) = mgr.restore(&path).unwrap();
    assert_eq!(restored_cycle, 30, "{tag}: restore cycle");
    mgr.submit_design(b, 20).unwrap();
    let cont_b = mgr.poll(b, usize::MAX, far()).unwrap();
    assert!(cont_b.done && cont_b.cycle == 50, "{tag}");

    assert_eq!(
        cont_a.records, cont_b.records,
        "{tag}: restored run diverged from the uninterrupted run"
    );
    assert_eq!(
        mgr.session_regs(a.session).unwrap(),
        mgr.session_regs(b).unwrap(),
        "{tag}: committed register slots differ after restore"
    );
    // Both sessions own their whole host, so their snapshots are full
    // kernel state — compare it outright (slots, activity, trackers).
    assert_eq!(
        mgr.snapshot(a.session).unwrap().payload,
        mgr.snapshot(b).unwrap().payload,
        "{tag}: full host state differs after restore"
    );

    mgr.close(a.session).unwrap();
    mgr.close(b).unwrap();
}

#[test]
fn checkpoint_restore_matrix_is_bit_identical() {
    let dir = tmp_dir("matrix");
    // One manager for the whole matrix so each (design, parts) compiles
    // once and the other cells replay it from the cache.
    let mut mgr = SessionManager::new(Some(dir.join("cache")), 8);
    for design in ["fir8", "tiny_cpu_divergent"] {
        for parts in [1usize, 4] {
            for lanes in [1usize, 8] {
                for sparse in [false, true] {
                    checkpoint_matrix_case(&mut mgr, &dir, design, parts, lanes, sparse);
                }
            }
        }
    }
}

/// A packed session (sharing a host with another session) snapshots as
/// a lane slice; restoring it onto a fresh host resumes bit-identically
/// while the original host and its other tenant keep running.
#[test]
fn packed_lane_slice_checkpoint_restores_bit_identical() {
    let dir = tmp_dir("slice");
    let mut mgr = SessionManager::new(None, 4);
    let first = mgr.open(&cfg("fir8", 1, 8, 2, false)).unwrap();
    let second = mgr.open(&cfg("fir8", 1, 8, 3, false)).unwrap();
    assert_eq!(first.host, second.host, "same-design sessions should pack");
    assert_eq!(second.lane0, 2, "contiguous packing after the width-2 slice");

    for id in [first.session, second.session] {
        mgr.submit_design(id, 25).unwrap();
        assert!(mgr.poll(id, usize::MAX, far()).unwrap().done);
    }
    let path = dir.join("slice.rtal");
    let (_, at) = mgr.checkpoint(second.session, &path).unwrap();
    assert_eq!(at, 25);

    for id in [first.session, second.session] {
        mgr.submit_design(id, 15).unwrap();
    }
    let cont = mgr.poll(second.session, usize::MAX, far()).unwrap();
    assert!(cont.done && cont.cycle == 40);

    let (restored, cycle) = mgr.restore(&path).unwrap();
    assert_eq!(cycle, 25);
    mgr.submit_design(restored, 15).unwrap();
    let cont_r = mgr.poll(restored, usize::MAX, far()).unwrap();
    assert_eq!(cont.records, cont_r.records, "restored slice diverged");
    assert_eq!(
        mgr.session_regs(second.session).unwrap(),
        mgr.session_regs(restored).unwrap()
    );
    // The host-mate was never disturbed: it still drains its own queue.
    let mate = mgr.poll(first.session, usize::MAX, far()).unwrap();
    assert!(mate.done && mate.cycle == 40);
}

/// Corrupted or truncated snapshot files are rejected with a structured
/// error from `restore` — never a panic, never a silently-wrong state.
#[test]
fn corrupt_snapshots_are_rejected_not_loaded() {
    let dir = tmp_dir("corrupt");
    let mut mgr = SessionManager::new(None, 4);
    let s = mgr.open(&cfg("counter", 1, 1, 1, false)).unwrap();
    mgr.submit_design(s.session, 10).unwrap();
    assert!(mgr.poll(s.session, usize::MAX, far()).unwrap().done);
    let path = dir.join("good.rtal");
    mgr.checkpoint(s.session, &path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert!(mgr.restore(&path).is_ok(), "the pristine file must load");

    // Single-byte corruption at several depths: header, config, payload,
    // checksum trailer.
    let bad_path = dir.join("bad.rtal");
    for pos in [0, 5, good.len() / 3, good.len() / 2, good.len() - 1] {
        let mut bad = good.clone();
        bad[pos] ^= 0x40;
        std::fs::write(&bad_path, &bad).unwrap();
        let err = mgr.restore(&bad_path).unwrap_err();
        assert!(!err.is_empty(), "flip at {pos}: empty error message");
    }
    // Truncations, including an empty file.
    for keep in [0, 3, good.len() / 2, good.len() - 1] {
        std::fs::write(&bad_path, &good[..keep]).unwrap();
        assert!(mgr.restore(&bad_path).is_err(), "truncated to {keep} bytes loaded");
    }
    // Missing file is an error, not a panic.
    assert!(mgr.restore(&dir.join("nope.rtal")).is_err());
}

/// The cache's reason to exist: once a design has been compiled under a
/// configuration, re-opening it — from memory or from the on-disk store
/// in a fresh process — costs < 10% of the cold compile+partition time.
#[test]
fn warm_open_is_under_ten_percent_of_cold_compile() {
    let dir = tmp_dir("warm");
    let design = catalog("rocket_like_1c").unwrap();

    let mut cold_cache = DesignCache::new(Some(dir.clone()), 4);
    let (_, cold) = cold_cache
        .open_design(&design, true, 4, PartitionerKind::MinCut)
        .unwrap();
    assert!(!cold.hit);
    assert_eq!(cold.source, OpenSource::Compiled);

    let (_, mem) = cold_cache
        .open_design(&design, true, 4, PartitionerKind::MinCut)
        .unwrap();
    assert!(mem.hit);
    assert_eq!(mem.source, OpenSource::Memory);

    // A fresh cache over the same directory models a server restart:
    // the open is answered from disk without recompiling.
    let mut disk_cache = DesignCache::new(Some(dir), 4);
    let (_, disk) = disk_cache
        .open_design(&design, true, 4, PartitionerKind::MinCut)
        .unwrap();
    assert!(disk.hit);
    assert_eq!(disk.source, OpenSource::Disk);

    let budget = cold.cold_compile.as_secs_f64() * 0.10;
    assert!(
        mem.open_time.as_secs_f64() < budget,
        "memory hit took {:?}, cold compile {:?}",
        mem.open_time,
        cold.cold_compile
    );
    assert!(
        disk.open_time.as_secs_f64() < budget,
        "disk hit took {:?}, cold compile {:?}",
        disk.open_time,
        cold.cold_compile
    );
}
