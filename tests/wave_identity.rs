//! Byte-identity of the activity-gated waveform sink (`sim::wave`)
//! against full value-diff references, across the whole execution grid:
//!
//! * **kernel mode** (P = 1): a dense or sparse batched kernel with a
//!   [`WaveSink`] per selected lane, versus a *scalar* kernel replaying
//!   that lane's stimulus through the plain [`VcdWriter`] full-diff
//!   `sample` path — every named slot is a variable;
//! * **outputs mode** (P = 4): a partitioned [`BatchParallelSim`] with
//!   outputs-only sinks, versus the scalar kernel's `outputs()` column
//!   through `VcdWriter::sample_values`.
//!
//! Grid: P ∈ {1, 4} × B ∈ {1, 8} × {dense, sparse} on `fir8`
//! (input-driven: exercises the input/group gating classes) and
//! `tiny_cpu_divergent` (self-driving with per-lane ROM programs:
//! exercises register gating, divergent lane_init replay, and the
//! quiescent tail after each lane halts). Identity is exact byte
//! equality of the VCD streams — headers, timestamps, change lines.

use rteaal::coordinator::compile::{compile_design, CompileOpts, Compiled};
use rteaal::coordinator::parallel::BatchParallelSim;
use rteaal::designs::{catalog, Design};
use rteaal::kernels::{build_batch, build_sparse, build_with_oim, KernelConfig};
use rteaal::sim::vcd::VcdWriter;
use rteaal::sim::WaveSink;

/// Compile a catalog design in waveform mode (no mux fusion, so named
/// internal signals survive as variables — the `--vcd` CLI setting).
fn compiled(name: &str) -> (Design, Compiled) {
    let d = catalog(name).unwrap_or_else(|| panic!("catalog has {name}"));
    let c = compile_design(&d, CompileOpts { fuse: false });
    (d, c)
}

/// Scalar full-diff reference over **every named slot**, replaying lane
/// `lane` of a `lanes`-wide batched run (stimulus and divergent-lane
/// initialization included).
fn scalar_all_slots(d: &Design, c: &Compiled, lane: usize, lanes: usize, cycles: u64) -> Vec<u8> {
    let mut k = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);
    for (slot, l, v) in d.resolved_lane_init(&c.graph, lanes) {
        if l == lane {
            k.poke(slot, v);
        }
    }
    let mut w = VcdWriter::new(&c.ir, Vec::new()).unwrap();
    let mut stim = d.make_stimulus_for_lane(lane);
    for cyc in 0..cycles {
        k.step(&stim(cyc));
        w.sample(cyc + 1, k.slots()).unwrap();
    }
    w.writer_mut().clone()
}

/// Scalar full-diff reference over the design's **output ports** only
/// (the variable set of a partitioned run), same replay rules.
fn scalar_outputs(d: &Design, c: &Compiled, lane: usize, lanes: usize, cycles: u64) -> Vec<u8> {
    let mut k = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);
    for (slot, l, v) in d.resolved_lane_init(&c.graph, lanes) {
        if l == lane {
            k.poke(slot, v);
        }
    }
    let mut w = VcdWriter::new_outputs(&c.ir, Vec::new()).unwrap();
    let mut stim = d.make_stimulus_for_lane(lane);
    for cyc in 0..cycles {
        k.step(&stim(cyc));
        let vals: Vec<u64> = k.outputs().into_iter().map(|(_, v)| v).collect();
        w.sample_values(cyc + 1, &vals).unwrap();
    }
    w.writer_mut().clone()
}

/// One batched kernel run with a mask-gated sink on each lane in
/// `wave_lanes`; returns each lane's VCD bytes.
fn batched_all_slots(
    d: &Design,
    c: &Compiled,
    sparse: bool,
    lanes: usize,
    wave_lanes: &[usize],
    cycles: u64,
) -> Vec<(usize, Vec<u8>)> {
    let mut k = if sparse {
        build_sparse(KernelConfig::PSU, &c.ir, &c.oim, lanes)
    } else {
        build_batch(KernelConfig::PSU, &c.ir, &c.oim, lanes)
    };
    d.apply_lane_init(&c.graph, k.as_mut());
    let mut sinks: Vec<WaveSink<Vec<u8>>> = wave_lanes
        .iter()
        .map(|&l| WaveSink::attach(&c.ir, k.as_ref(), l, Vec::new()).unwrap())
        .collect();
    let mut stim = d.make_lane_stimulus(lanes);
    for cyc in 0..cycles {
        k.step(&stim(cyc));
        for s in &mut sinks {
            s.sample_kernel(cyc + 1, k.as_ref()).unwrap();
        }
    }
    wave_lanes.iter().copied().zip(sinks.iter_mut().map(WaveSink::take_chunk)).collect()
}

/// One partitioned run with an outputs-only sink on each lane in
/// `wave_lanes`; returns each lane's VCD bytes.
fn parallel_outputs(
    d: &Design,
    c: &Compiled,
    sparse: bool,
    parts: usize,
    lanes: usize,
    wave_lanes: &[usize],
    cycles: u64,
) -> Vec<(usize, Vec<u8>)> {
    let mut sim = BatchParallelSim::new(&c.ir, KernelConfig::PSU, parts, lanes, sparse);
    for (slot, l, v) in d.resolved_lane_init(&c.graph, lanes) {
        sim.poke_lane(slot, l, v);
    }
    let mut sinks: Vec<WaveSink<Vec<u8>>> = wave_lanes
        .iter()
        .map(|&l| WaveSink::attach_outputs(&c.ir, l, Vec::new()).unwrap())
        .collect();
    let mut stim = d.make_lane_stimulus(lanes);
    let mut buf: Vec<(String, u64)> = Vec::new();
    for cyc in 0..cycles {
        sim.step(&stim(cyc));
        for s in &mut sinks {
            s.sample_parallel(cyc + 1, &sim, &mut buf).unwrap();
        }
    }
    wave_lanes.iter().copied().zip(sinks.iter_mut().map(WaveSink::take_chunk)).collect()
}

fn assert_identical(
    kind: &str,
    design: &str,
    sparse: bool,
    lanes: usize,
    lane: usize,
    got: &[u8],
    want: &[u8],
) {
    assert!(!want.is_empty(), "{kind} {design}: empty reference stream");
    assert_eq!(
        String::from_utf8_lossy(got),
        String::from_utf8_lossy(want),
        "{kind}: {design} sparse={sparse} B={lanes} lane={lane} diverged \
         from the scalar full-diff reference"
    );
}

fn kernel_mode_grid(design: &str, cycles: u64) {
    let (d, c) = compiled(design);
    for sparse in [false, true] {
        for &lanes in &[1usize, 8] {
            let wave_lanes: &[usize] = if lanes == 1 { &[0] } else { &[0, 3, 7] };
            let runs = batched_all_slots(&d, &c, sparse, lanes, wave_lanes, cycles);
            for (lane, bytes) in runs {
                let reference = scalar_all_slots(&d, &c, lane, lanes, cycles);
                assert_identical("kernel-mode", design, sparse, lanes, lane, &bytes, &reference);
            }
        }
    }
}

fn outputs_mode_grid(design: &str, cycles: u64) {
    let (d, c) = compiled(design);
    let parts = 4;
    for sparse in [false, true] {
        for &lanes in &[1usize, 8] {
            let wave_lanes: &[usize] = if lanes == 1 { &[0] } else { &[0, 3, 7] };
            let runs = parallel_outputs(&d, &c, sparse, parts, lanes, wave_lanes, cycles);
            for (lane, bytes) in runs {
                let reference = scalar_outputs(&d, &c, lane, lanes, cycles);
                assert_identical("outputs-mode", design, sparse, lanes, lane, &bytes, &reference);
            }
        }
    }
}

/// P = 1, every named slot: dense and sparse batched sinks equal the
/// scalar full-diff writer on the input-driven FIR.
#[test]
fn kernel_mode_fir8() {
    kernel_mode_grid("fir8", 48);
}

/// P = 1 on the divergent-ROM CPU: per-lane programs replayed through
/// lane_init, register/group gating, and the post-halt quiescent tail.
#[test]
fn kernel_mode_tiny_cpu_divergent() {
    kernel_mode_grid("tiny_cpu_divergent", 220);
}

/// P = 4, output ports: the partitioned sink (lane-gated by
/// `wave_changed`) equals the scalar outputs-only reference.
#[test]
fn outputs_mode_fir8() {
    outputs_mode_grid("fir8", 48);
}

/// P = 4 on the divergent-ROM CPU (lane_init lands through
/// `BatchParallelSim::poke_lane`, which also dirties the wave mask —
/// over-approximation that must not change a single byte).
#[test]
fn outputs_mode_tiny_cpu_divergent() {
    outputs_mode_grid("tiny_cpu_divergent", 220);
}

/// A sink attached to a session restored from a checkpoint opens a
/// *fresh* VCD stream: exactly one header, a complete value dump of the
/// restored state at its first sample, and byte-identity with a sink
/// attached to the uninterrupted session at the same cycle.
#[test]
fn wave_sink_on_restored_session_starts_with_header_and_full_dump() {
    use rteaal::service::session::{SessionConfig, SessionManager};
    use std::time::{Duration, Instant};

    let far = || Instant::now() + Duration::from_secs(300);
    let mut mgr = SessionManager::new(None, 4);
    let cfg = SessionConfig { design: "fir8".into(), ..SessionConfig::default() };
    let a = mgr.open(&cfg).unwrap();
    mgr.submit_design(a.session, 30).unwrap();
    assert!(mgr.poll(a.session, usize::MAX, far()).unwrap().done);
    let snap = mgr.snapshot(a.session).unwrap();

    // reference: attach on the uninterrupted session at cycle 30
    mgr.attach_wave(a.session, 0).unwrap();
    mgr.submit_design(a.session, 20).unwrap();
    let ra = mgr.poll(a.session, usize::MAX, far()).unwrap();
    assert!(ra.done, "reference run did not finish");
    let want = ra.wave_chunk.expect("sink attached");

    // restored-from-checkpoint session, sink attached at the same point
    let (b, cycle) = mgr.restore_snapshot(&snap).unwrap();
    assert_eq!(cycle, 30, "restore resumes at the checkpoint cycle");
    mgr.attach_wave(b, 0).unwrap();
    mgr.submit_design(b, 20).unwrap();
    let rb = mgr.poll(b, usize::MAX, far()).unwrap();
    assert!(rb.done, "restored run did not finish");
    let got = rb.wave_chunk.expect("sink attached");

    let text = String::from_utf8_lossy(&got).to_string();
    assert_eq!(text.matches("$enddefinitions").count(), 1, "exactly one fresh header");
    let vars = text.matches("$var ").count();
    assert!(vars > 0, "header declares variables");
    let body = text.split_once("$enddefinitions $end\n").expect("header terminator").1;
    let mut lines = body.lines();
    assert_eq!(lines.next(), Some("#31"), "first sample right after the restore cycle");
    let first_dump = lines.take_while(|l| !l.starts_with('#')).count();
    assert_eq!(first_dump, vars, "first sample dumps every variable of the restored state");
    assert_eq!(
        String::from_utf8_lossy(&want).to_string(),
        text,
        "restored stream diverged from the uninterrupted session's"
    );
}
