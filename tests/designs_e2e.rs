//! End-to-end design suites: the real designs do their real jobs under
//! every kernel configuration.

use rteaal::coordinator::compile::{compile_design, CompileOpts, Compiled};
use rteaal::coordinator::parallel::{BatchParallelSim, ParallelSim};
use rteaal::designs::keccak::{keccak_f_sw, keccak_round_datapath};
use rteaal::designs::tiny_cpu::{
    dhrystone_like, golden_run, lane_rom_init, tiny_cpu, tiny_cpu_divergent,
};
use rteaal::designs::{catalog, Design, Stimulus};
use rteaal::graph::RefSim;
use rteaal::kernels::{
    build_batch, build_sparse, build_with_oim, BatchKernel, KernelConfig, ALL_KERNELS,
};
use rteaal::partition::PartitionerKind;

/// tiny_cpu runs its program to the golden checksum under all 7 kernels.
#[test]
fn tiny_cpu_checksum_under_every_kernel() {
    let prog = dhrystone_like(12);
    let (golden, steps) = golden_run(&prog, 100_000);
    let d = Design {
        name: "tiny".into(),
        graph: tiny_cpu(&prog),
        stimulus: Stimulus::Zero,
        default_cycles: 0,
        lane_init: vec![],
    };
    let c = compile_design(&d, CompileOpts::default());
    for cfg in ALL_KERNELS {
        let mut k = build_with_oim(cfg, &c.ir, &c.oim);
        let mut halted_at = None;
        for cycle in 0..10_000u64 {
            k.step(&[0, 0, 0, 0]);
            if k.outputs().iter().any(|(n, v)| n == "halted" && *v == 1) {
                halted_at = Some(cycle + 1);
                break;
            }
        }
        let halted_at = halted_at.unwrap_or_else(|| panic!("{} never halted", cfg.name()));
        assert_eq!(halted_at, steps as u64 + 1, "{} cycle count", cfg.name());
        let checksum =
            k.outputs().iter().find(|(n, _)| n == "checksum").map(|(_, v)| *v).unwrap();
        assert_eq!(checksum, golden as u64, "{} checksum", cfg.name());
    }
}

/// The keccak datapath computes true Keccak-f[1600] permutations under
/// rolled and unrolled kernels (two full permutations back to back).
#[test]
fn keccak_double_permutation_under_kernels() {
    let d = Design {
        name: "keccak".into(),
        graph: keccak_round_datapath(),
        stimulus: Stimulus::Zero,
        default_cycles: 0,
        lane_init: vec![],
    };
    let c = compile_design(&d, CompileOpts::default());
    let ins: [u64; 5] = [0x1111, 0x2222, 0x3333, 0x4444, 0x5555];
    let mut golden = [[0u64; 5]; 5];
    for x in 0..5 {
        for y in 0..5 {
            golden[x][y] = ins[x].rotate_left((y * 7) as u32) ^ y as u64;
        }
    }
    keccak_f_sw(&mut golden);

    for cfg in [KernelConfig::RU, KernelConfig::PSU, KernelConfig::TI] {
        let mut k = build_with_oim(cfg, &c.ir, &c.oim);
        let mut load = vec![1u64, 0];
        load.extend_from_slice(&ins);
        k.step(&load);
        let go = vec![0u64, 1, 0, 0, 0, 0, 0];
        for _ in 0..24 {
            k.step(&go);
        }
        let outs: std::collections::HashMap<String, u64> = k.outputs().into_iter().collect();
        assert_eq!(outs["lane00"], golden[0][0], "{}", cfg.name());
        assert_eq!(outs["lane12"], golden[1][2], "{}", cfg.name());
        assert_eq!(outs["lane44"], golden[4][4], "{}", cfg.name());
    }
}

/// Every catalog design simulates deterministically: the same stimulus
/// seed gives the same outputs under different kernels.
#[test]
fn catalog_designs_cross_kernel_determinism() {
    for name in ["counter", "alu32", "fir8", "gemmini_like_4", "rocket_like_1c", "boom_like_1c"] {
        let d = catalog(name).unwrap();
        let c = compile_design(&d, CompileOpts::default());
        let mut psu = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);
        let mut ti = build_with_oim(KernelConfig::TI, &c.ir, &c.oim);
        let mut ru = build_with_oim(KernelConfig::RU, &c.ir, &c.oim);
        let mut stim = d.make_stimulus();
        for cycle in 0..50u64 {
            let inputs = stim(cycle);
            psu.step(&inputs);
            ti.step(&inputs);
            ru.step(&inputs);
            assert_eq!(psu.outputs(), ti.outputs(), "{name} cycle {cycle}");
            assert_eq!(psu.outputs(), ru.outputs(), "{name} cycle {cycle}");
        }
    }
}

/// The partitioned (RepCut-style) simulator agrees with the graph
/// reference interpreter on catalog designs over 1/2/4 partitions for 64
/// cycles — the coordinator's multi-threaded path against the semantic
/// oracle, on real designs rather than random circuits.
#[test]
fn parallel_sim_matches_refsim_on_catalog_designs() {
    for name in ["fir8", "gemmini_like_4"] {
        let d = catalog(name).unwrap();
        let c = compile_design(&d, CompileOpts::default());
        for parts in [1usize, 2, 4] {
            let mut par = ParallelSim::new(&c.ir, KernelConfig::PSU, parts);
            let mut reference = RefSim::new(c.graph.clone());
            let mut stim = d.make_stimulus();
            for cycle in 0..64u64 {
                let inputs = stim(cycle);
                reference.step(&inputs);
                par.step(&inputs);
                assert_eq!(
                    par.outputs(),
                    reference.outputs(),
                    "{name} parts={parts} cycle={cycle}"
                );
            }
        }
    }
}

/// One cell of the partitions × lanes differential grid: a
/// `BatchParallelSim` over (parts, lanes) — under the given register
/// partitioner, optionally in sparse (partition-skipping) mode — against
/// one graph reference interpreter **per lane**, checking named outputs
/// *and* every committed register slot, every cycle. Divergent-lane
/// register initialization (`Design::lane_init`) is replayed on both
/// sides.
fn grid_check_against_refsim(
    d: &Design,
    c: &Compiled,
    parts: usize,
    lanes: usize,
    cycles: u64,
    partitioner: PartitionerKind,
    sparse: bool,
) {
    let mut par = BatchParallelSim::with_partitioner(
        &c.ir,
        KernelConfig::PSU,
        parts,
        lanes,
        sparse,
        partitioner,
    );
    let pokes = d.resolved_lane_init(&c.graph, lanes);
    for &(slot, lane, value) in &pokes {
        par.poke_lane(slot, lane, value);
    }
    let mut refs: Vec<RefSim> = (0..lanes).map(|_| RefSim::new(c.graph.clone())).collect();
    for &(slot, lane, value) in &pokes {
        refs[lane].poke(slot, value);
    }
    let mut stims: Vec<_> = (0..lanes).map(|l| d.make_stimulus_for_lane(l)).collect();
    let n_inputs = c.graph.inputs.len();
    let mut out_buf: Vec<(String, u64)> = Vec::new();
    for cycle in 0..cycles {
        let per_lane: Vec<Vec<u64>> = stims.iter_mut().map(|s| s(cycle)).collect();
        let mut flat = vec![0u64; n_inputs * lanes];
        for (l, inp) in per_lane.iter().enumerate() {
            for (i, &v) in inp.iter().enumerate() {
                flat[i * lanes + l] = v;
            }
        }
        par.step(&flat);
        for (l, r) in refs.iter_mut().enumerate() {
            r.step(&per_lane[l]);
        }
        for (l, r) in refs.iter().enumerate() {
            par.write_lane_outputs(l, &mut out_buf);
            assert_eq!(
                out_buf,
                r.outputs(),
                "{} {} sparse={sparse} P={parts} B={lanes} lane={l} cycle={cycle}",
                d.name,
                partitioner.name()
            );
            for &(reg, _, _) in &c.ir.commits {
                assert_eq!(
                    par.reg_lane(reg, l),
                    r.value(reg),
                    "{} {} sparse={sparse} P={parts} B={lanes} lane={l} cycle={cycle} reg slot {reg}",
                    d.name,
                    partitioner.name()
                );
            }
        }
    }
}

/// The three real designs the differential grids run over — including
/// the divergent-lane register-ROM tiny_cpu, whose pure-ROM `rom{i}`
/// registers exercise the never-written ownership fix.
fn grid_designs() -> Vec<Design> {
    let prog_a = dhrystone_like(12);
    let prog_b = dhrystone_like(7);
    let rom_words = 32;
    let divergent = Design {
        name: "tiny_cpu_divergent".into(),
        graph: tiny_cpu_divergent(rom_words, &prog_a),
        stimulus: Stimulus::Zero,
        default_cycles: 0,
        lane_init: lane_rom_init(rom_words, &[prog_a, prog_b]),
    };
    vec![catalog("fir8").unwrap(), catalog("gemmini_like_4").unwrap(), divergent]
}

/// The headline partitions × lanes differential grid: `BatchParallelSim`
/// under the default min-cut partitioner is bit-identical **per lane**
/// to the graph reference interpreter on real designs — including the
/// divergent-lane register-ROM tiny_cpu — across P ∈ {1, 2, 4} ×
/// B ∈ {1, 8, 64}, 64 cycles each, checking outputs and committed
/// register slots every cycle.
#[test]
fn batch_parallel_grid_matches_refsim_per_lane() {
    for d in &grid_designs() {
        let c = compile_design(d, CompileOpts::default());
        for parts in [1usize, 2, 4] {
            for lanes in [1usize, 8, 64] {
                grid_check_against_refsim(d, &c, parts, lanes, 64, PartitionerKind::MinCut, false);
            }
        }
    }
}

/// The same differential grid under the round-robin baseline partitioner
/// (reduced to the multi-partition corner — P = 1 is
/// partitioner-independent): ownership strategy must never change
/// behaviour.
#[test]
fn batch_parallel_grid_matches_refsim_round_robin() {
    for d in &grid_designs() {
        let c = compile_design(d, CompileOpts::default());
        for parts in [2usize, 4] {
            for lanes in [1usize, 8] {
                grid_check_against_refsim(
                    d,
                    &c,
                    parts,
                    lanes,
                    64,
                    PartitionerKind::RoundRobin,
                    false,
                );
            }
        }
    }
}

/// The same differential grid in sparse (partition-skipping) mode under
/// min-cut ownership: activity-masked partitioned runs stay bit-identical
/// to the per-lane reference interpreter, including across the divergent
/// ROM's pre-run pokes (`B ≤ 64` for the lane masks).
#[test]
fn batch_parallel_grid_matches_refsim_sparse_mincut() {
    for d in &grid_designs() {
        let c = compile_design(d, CompileOpts::default());
        for parts in [2usize, 4] {
            for lanes in [8usize, 64] {
                grid_check_against_refsim(d, &c, parts, lanes, 64, PartitionerKind::MinCut, true);
            }
        }
    }
}

/// Sparse kernels now run *inside* partitions: with `sparse = true` and
/// a group-capable kernel (PSU here), `BatchParallelSim` builds one
/// group-masked sparse executor per partition and feeds the RUM
/// exchange's per-register per-lane change bits into the destination
/// trackers through the targeted `poke_lane` — no recold anywhere. The
/// composed run must be **bit-identical** to the dense partitioned
/// simulator across P ∈ {1, 2, 4} × B ∈ {1, 8, 64} ×
/// toggle ∈ {0, 0.05, 1} on fir8, gemmini_like_8 and the divergent-ROM
/// tiny_cpu (whose pre-run pokes exercise targeted invalidation),
/// checking named outputs and committed registers every cycle.
#[test]
fn sparse_inside_partitions_matches_dense_partitioned() {
    let prog_a = dhrystone_like(12);
    let prog_b = dhrystone_like(7);
    let rom_words = 32;
    let divergent = Design {
        name: "tiny_cpu_divergent".into(),
        graph: tiny_cpu_divergent(rom_words, &prog_a),
        stimulus: Stimulus::Zero,
        default_cycles: 0,
        lane_init: lane_rom_init(rom_words, &[prog_a, prog_b]),
    };
    let designs = vec![catalog("fir8").unwrap(), catalog("gemmini_like_8").unwrap(), divergent];
    for d in &designs {
        let mut buf_dense: Vec<(String, u64)> = Vec::new();
        let mut buf_sparse: Vec<(String, u64)> = Vec::new();
        let c = compile_design(d, CompileOpts::default());
        for parts in [1usize, 2, 4] {
            for lanes in [1usize, 8, 64] {
                for &rate in &[0.0f64, 0.05, 1.0] {
                    let mut dense =
                        BatchParallelSim::new(&c.ir, KernelConfig::PSU, parts, lanes, false);
                    let mut sparse =
                        BatchParallelSim::new(&c.ir, KernelConfig::PSU, parts, lanes, true);
                    for &(slot, lane, value) in &d.resolved_lane_init(&c.graph, lanes) {
                        dense.poke_lane(slot, lane, value);
                        sparse.poke_lane(slot, lane, value);
                    }
                    let mut stim_a = d.make_lane_stimulus_toggle(lanes, rate);
                    let mut stim_b = d.make_lane_stimulus_toggle(lanes, rate);
                    for cycle in 0..32u64 {
                        let inputs = stim_a(cycle);
                        assert_eq!(inputs, stim_b(cycle), "stimulus streams must agree");
                        dense.step(&inputs);
                        sparse.step(&inputs);
                        for l in [0, lanes - 1] {
                            dense.write_lane_outputs(l, &mut buf_dense);
                            sparse.write_lane_outputs(l, &mut buf_sparse);
                            assert_eq!(
                                buf_dense, buf_sparse,
                                "{} P={parts} B={lanes} rate={rate} lane={l} cycle={cycle}",
                                d.name
                            );
                        }
                        for &(reg, _, _) in &c.ir.commits {
                            for l in [0, lanes - 1] {
                                assert_eq!(
                                    sparse.reg_lane(reg, l),
                                    dense.reg_lane(reg, l),
                                    "{} P={parts} B={lanes} rate={rate} reg={reg} lane={l} cycle={cycle}",
                                    d.name
                                );
                            }
                        }
                    }
                    // the composed run reports both activity levels;
                    // the dense run reports neither
                    assert!(sparse.activity_stats().is_some());
                    assert!(sparse.group_stats().is_some());
                    assert!(dense.activity_stats().is_none());
                    assert!(dense.group_stats().is_none());
                }
            }
        }
    }
}

/// The batched TI kernel reproduces the tiny_cpu golden checksum on
/// *every* lane when all lanes run the same (self-driving) program —
/// the end-to-end workload under the throughput engine.
#[test]
fn batched_ti_tiny_cpu_checksum_on_every_lane() {
    let prog = dhrystone_like(12);
    let (golden, steps) = golden_run(&prog, 100_000);
    let d = Design {
        name: "tiny".into(),
        graph: tiny_cpu(&prog),
        stimulus: Stimulus::Zero,
        default_cycles: 0,
        lane_init: vec![],
    };
    let c = compile_design(&d, CompileOpts::default());
    for lanes in [1usize, 3, 8] {
        let mut k = build_batch(KernelConfig::TI, &c.ir, &c.oim, lanes);
        let zeros = vec![0u64; 4 * lanes];
        let mut halted_at = None;
        for cycle in 0..10_000u64 {
            k.step(&zeros);
            if k.lane_outputs(0).iter().any(|(n, v)| n == "halted" && *v == 1) {
                halted_at = Some(cycle + 1);
                break;
            }
        }
        let halted_at = halted_at.unwrap_or_else(|| panic!("lanes={lanes}: never halted"));
        assert_eq!(halted_at, steps as u64 + 1, "lanes={lanes} cycle count");
        for lane in 0..lanes {
            let outs: std::collections::HashMap<String, u64> =
                k.lane_outputs(lane).into_iter().collect();
            assert_eq!(outs["halted"], 1, "lane {lane} of {lanes} not halted");
            assert_eq!(outs["checksum"], golden as u64, "lane {lane} of {lanes} checksum");
        }
    }
}

/// Divergent lanes: a register-ROM tiny_cpu with **two distinct per-lane
/// programs** (via `Design::lane_init`) reaches each program's own golden
/// checksum on the right lanes — one OIM walk / tape, different software
/// per lane. Runs under the dense batched executors at three binding
/// levels (TI, plus the flattened-program IU and straight-line-tape SU)
/// and the sparse activity-masked TI one (which must survive the pre-run
/// pokes).
#[test]
fn divergent_lane_roms_reach_their_own_golden_checksums() {
    let prog_a = dhrystone_like(12);
    let prog_b = dhrystone_like(7);
    let (golden_a, steps_a) = golden_run(&prog_a, 100_000);
    let (golden_b, steps_b) = golden_run(&prog_b, 100_000);
    assert_ne!(golden_a, golden_b, "programs must be distinguishable");
    assert_ne!(steps_a, steps_b);

    let rom_words = 32;
    let d = Design {
        name: "tiny_divergent".into(),
        graph: tiny_cpu_divergent(rom_words, &prog_a),
        stimulus: Stimulus::Zero,
        default_cycles: 0,
        lane_init: lane_rom_init(rom_words, &[prog_a.clone(), prog_b.clone()]),
    };
    let c = compile_design(&d, CompileOpts::default());
    let lanes = 4usize; // lanes 0, 2 run prog_a; lanes 1, 3 run prog_b
    let max_cycles = 1 + steps_a.max(steps_b) as u64;
    let runs: Vec<(Box<dyn BatchKernel>, bool)> = vec![
        (build_batch(KernelConfig::TI, &c.ir, &c.oim, lanes), false),
        (build_batch(KernelConfig::IU, &c.ir, &c.oim, lanes), false),
        (build_batch(KernelConfig::SU, &c.ir, &c.oim, lanes), false),
        (build_sparse(KernelConfig::TI, &c.ir, &c.oim, lanes), true),
    ];
    for (mut k, sparse) in runs {
        let name = k.config_name();
        d.apply_lane_init(&c.graph, k.as_mut());
        let zeros = vec![0u64; 4 * lanes];
        for _ in 0..max_cycles + 4 {
            k.step(&zeros);
        }
        for lane in 0..lanes {
            let outs: std::collections::HashMap<String, u64> =
                k.lane_outputs(lane).into_iter().collect();
            let (golden, which) =
                if lane % 2 == 0 { (golden_a, "A") } else { (golden_b, "B") };
            assert_eq!(outs["halted"], 1, "{name} sparse={sparse} lane {lane} not halted");
            assert_eq!(
                outs["checksum"], golden as u64,
                "{name} sparse={sparse} lane {lane} (program {which}) checksum"
            );
        }
        if sparse {
            // the two fast lanes halt early, so a real fraction of the
            // op-lane work must have been skipped
            let stats = k.activity_stats().unwrap();
            assert!(stats.skip_rate() > 0.0, "divergent sparse run skipped nothing");
        }
    }
}

/// The divergent-ROM build with a single program behaves exactly like the
/// constant-ROM build (same checksum, same halt cycle) — the register ROM
/// is an encoding change, not a behaviour change.
#[test]
fn divergent_rom_build_matches_const_rom_build() {
    let prog = dhrystone_like(5);
    let (golden, steps) = golden_run(&prog, 100_000);
    for graph in [tiny_cpu(&prog), tiny_cpu_divergent(32, &prog)] {
        let mut sim = RefSim::new(graph);
        let mut halted_at = None;
        for cycle in 0..5_000u64 {
            sim.step(&[0, 0, 0, 0]);
            let outs: std::collections::HashMap<String, u64> =
                sim.outputs().into_iter().collect();
            if outs["halted"] == 1 {
                assert_eq!(outs["checksum"], golden as u64);
                halted_at = Some(cycle + 1);
                break;
            }
        }
        assert_eq!(halted_at, Some(steps as u64 + 1));
    }
}

/// Waveform capture produces consistent VCD output across kernels
/// (value-change records depend only on design behaviour).
#[test]
fn vcd_identical_across_kernels() {
    use rteaal::sim::vcd::VcdWriter;
    let d = catalog("counter").unwrap();
    let c = compile_design(&d, CompileOpts { fuse: false });
    let dir = std::env::temp_dir().join("rteaal_vcd_x");
    std::fs::create_dir_all(&dir).unwrap();
    let mut texts = Vec::new();
    for cfg in [KernelConfig::OU, KernelConfig::SU] {
        let mut k = build_with_oim(cfg, &c.ir, &c.oim);
        let path = dir.join(format!("{}.vcd", cfg.name()));
        let mut w = VcdWriter::create(&c.ir, &path).unwrap();
        let mut stim = d.make_stimulus();
        for cycle in 1..=40u64 {
            k.step(&stim(cycle - 1));
            w.sample(cycle, k.slots()).unwrap();
        }
        w.finish().unwrap();
        texts.push(std::fs::read_to_string(&path).unwrap());
    }
    assert_eq!(texts[0], texts[1]);
}

/// Compile costs scale roughly linearly in design size (the paper's
/// headline compile claim is near-constant cost vs baselines' blowup).
#[test]
fn compile_cost_scales_linearly() {
    let t1 = {
        let d = catalog("rocket_like_1c").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        (c.compile_time, c.ir.total_ops())
    };
    let t4 = {
        let d = catalog("rocket_like_4c").unwrap();
        let c = compile_design(&d, CompileOpts::default());
        (c.compile_time, c.ir.total_ops())
    };
    let ops_ratio = t4.1 as f64 / t1.1 as f64;
    let time_ratio = t4.0.as_secs_f64() / t1.0.as_secs_f64().max(1e-9);
    // allow generous slack (allocator noise) but catch superlinear blowup
    assert!(
        time_ratio < ops_ratio * 4.0,
        "compile time ratio {time_ratio:.1} vs ops ratio {ops_ratio:.1}"
    );
}
