//! Cross-layer integration: the AOT XLA/PJRT backend must agree bit-exactly
//! with the native interpreter kernels on the same design + stimulus.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent,
//! e.g. in a bare `cargo test` before the first build).

use std::path::Path;

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::catalog;
use rteaal::kernels::{build_with_oim, KernelConfig};
use rteaal::runtime::pjrt::PjrtRuntime;
use rteaal::runtime::XlaBackend;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("tiny_cpu.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn xla_backend_matches_interpreter_tiny_cpu() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let mut xla = XlaBackend::load(&rt, dir, "tiny_cpu").expect("load artifacts");

    // native interpreter on the same (unfused) compile
    let d = catalog("tiny_cpu").unwrap();
    let c = compile_design(&d, CompileOpts { fuse: false });
    let mut native = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);

    // run whole chunks in lockstep; compare outputs at chunk boundaries
    let cycles = 8 * xla.chunk as u64;
    let mut stim = d.make_stimulus();
    let mut inputs_at = |c: u64| stim(c);
    let mut native_outs_at_boundary = Vec::new();
    for cyc in 0..cycles {
        native.step(&inputs_at(cyc));
        if (cyc + 1) % xla.chunk as u64 == 0 {
            native_outs_at_boundary.push(native.outputs());
        }
    }
    let mut stim2 = d.make_stimulus();
    let mut boundary = 0usize;
    for cyc in 0..cycles {
        let flushed = xla.step(&stim2(cyc)).expect("xla step");
        if flushed {
            assert_eq!(
                xla.outputs(),
                native_outs_at_boundary[boundary],
                "chunk boundary {boundary}"
            );
            boundary += 1;
        }
    }
    assert_eq!(boundary, 8);
}

#[test]
fn xla_backend_matches_interpreter_rocket_xs() {
    let Some(dir) = artifacts_dir() else { return };
    if !dir.join("rocket_like_xs.hlo.txt").exists() {
        return;
    }
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let mut xla = XlaBackend::load(&rt, dir, "rocket_like_xs").expect("load artifacts");
    let d = catalog("rocket_like_xs").unwrap();
    let c = compile_design(&d, CompileOpts { fuse: false });
    let mut native = build_with_oim(KernelConfig::TI, &c.ir, &c.oim);

    let cycles = 4 * xla.chunk as u64;
    let mut stim = d.make_stimulus();
    let mut native_boundaries = Vec::new();
    for cyc in 0..cycles {
        native.step(&stim(cyc));
        if (cyc + 1) % xla.chunk as u64 == 0 {
            native_boundaries.push(native.outputs());
        }
    }
    let mut stim2 = d.make_stimulus();
    let mut boundary = 0usize;
    for cyc in 0..cycles {
        if xla.step(&stim2(cyc)).expect("xla step") {
            assert_eq!(xla.outputs(), native_boundaries[boundary], "boundary {boundary}");
            boundary += 1;
        }
    }
}
