//! Cross-layer integration: the AOT XLA/PJRT backend must agree bit-exactly
//! with the native interpreter kernels on the same design + stimulus.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent,
//! e.g. in a bare `cargo test` before the first build).

use std::path::Path;

use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::catalog;
use rteaal::kernels::{build_with_oim, KernelConfig};
use rteaal::runtime::pjrt::PjrtRuntime;
use rteaal::runtime::XlaBackend;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("tiny_cpu.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn xla_backend_matches_interpreter_tiny_cpu() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let mut xla = XlaBackend::load(&rt, dir, "tiny_cpu").expect("load artifacts");

    // native interpreter on the same (unfused) compile
    let d = catalog("tiny_cpu").unwrap();
    let c = compile_design(&d, CompileOpts { fuse: false });
    let mut native = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);

    // run whole chunks in lockstep; compare outputs at chunk boundaries
    let cycles = 8 * xla.chunk as u64;
    let mut stim = d.make_stimulus();
    let mut inputs_at = |c: u64| stim(c);
    let mut native_outs_at_boundary = Vec::new();
    for cyc in 0..cycles {
        native.step(&inputs_at(cyc));
        if (cyc + 1) % xla.chunk as u64 == 0 {
            native_outs_at_boundary.push(native.outputs());
        }
    }
    let mut stim2 = d.make_stimulus();
    let mut boundary = 0usize;
    for cyc in 0..cycles {
        let flushed = xla.step(&stim2(cyc)).expect("xla step");
        if flushed {
            assert_eq!(
                xla.outputs(),
                native_outs_at_boundary[boundary],
                "chunk boundary {boundary}"
            );
            boundary += 1;
        }
    }
    assert_eq!(boundary, 8);
}

/// The partial-chunk peek is exact: `run(cycles)` with `cycles` not a
/// multiple of the chunk reports the last *real* cycle's outputs and does
/// not advance the committed state past it — continuing afterwards stays
/// in lockstep with the native interpreter, because the re-buffered real
/// rows replay in the next full chunk.
#[test]
fn xla_backend_partial_chunk_run_is_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let mut xla = XlaBackend::load(&rt, dir, "tiny_cpu").expect("load artifacts");
    let d = catalog("tiny_cpu").unwrap();
    let c = compile_design(&d, CompileOpts { fuse: false });
    let mut native = build_with_oim(KernelConfig::PSU, &c.ir, &c.oim);

    let chunk = xla.chunk as u64;
    if chunk < 2 {
        return; // no partial chunks to exercise
    }
    let partial = chunk + chunk / 2 + 1;
    assert_ne!(partial % chunk, 0, "must land mid-chunk");
    let mut stim = d.make_stimulus();
    xla.run(partial, |cyc| stim(cyc)).expect("xla run");
    let mut stim2 = d.make_stimulus();
    for cyc in 0..partial {
        native.step(&stim2(cyc));
    }
    assert_eq!(xla.outputs(), native.outputs(), "outputs at the partial cycle");

    // continue past the peek: the buffered rows replay in the next full
    // chunk, so the next flush lands exactly at cycle 2 * chunk
    let mut flushed_at = None;
    for cyc in partial..3 * chunk {
        native.step(&stim2(cyc));
        if xla.step(&stim(cyc)).expect("xla step") {
            flushed_at = Some(cyc + 1);
            break;
        }
    }
    assert_eq!(flushed_at, Some(2 * chunk), "the peek must not consume the buffered rows");
    assert_eq!(xla.outputs(), native.outputs(), "outputs after continuing past the peek");
}

#[test]
fn xla_backend_matches_interpreter_rocket_xs() {
    let Some(dir) = artifacts_dir() else { return };
    if !dir.join("rocket_like_xs.hlo.txt").exists() {
        return;
    }
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let mut xla = XlaBackend::load(&rt, dir, "rocket_like_xs").expect("load artifacts");
    let d = catalog("rocket_like_xs").unwrap();
    let c = compile_design(&d, CompileOpts { fuse: false });
    let mut native = build_with_oim(KernelConfig::TI, &c.ir, &c.oim);

    let cycles = 4 * xla.chunk as u64;
    let mut stim = d.make_stimulus();
    let mut native_boundaries = Vec::new();
    for cyc in 0..cycles {
        native.step(&stim(cyc));
        if (cyc + 1) % xla.chunk as u64 == 0 {
            native_boundaries.push(native.outputs());
        }
    }
    let mut stim2 = d.make_stimulus();
    let mut boundary = 0usize;
    for cyc in 0..cycles {
        if xla.step(&stim2(cyc)).expect("xla step") {
            assert_eq!(xla.outputs(), native_boundaries[boundary], "boundary {boundary}");
            boundary += 1;
        }
    }
}
