//! Seeded-fault corpus for the static artifact verifier (`rteaal check`).
//!
//! One mutator per diagnostic code: each test plants a minimal, targeted
//! fault in an otherwise-pristine artifact bundle and asserts that the
//! intended code fires. Collateral findings are allowed (a planted fault
//! may legitimately trip more than one invariant); what is asserted is
//! that the *intended* detector sees it. The pristine complement — clean
//! catalog designs, cold and through the incremental (cone-delta splice)
//! path — closes the loop: the verifier accepts exactly the artifacts the
//! compiler produces and rejects every seeded corruption.

use rteaal::activity::gdg::GroupDepGraph;
use rteaal::analysis::{verify_artifacts, Report};
use rteaal::coordinator::compile::{compile_design, CompileOpts};
use rteaal::designs::catalog;
use rteaal::graph::ops::mask;
use rteaal::partition::{never_written, partition_ir, PartitionerKind, Partitioning, TrackedReg};
use rteaal::service::cache::DesignCache;
use rteaal::tensor::ir::{KOp, LayerIr};
use rteaal::tensor::oim::Oim;
use rteaal::util::json::{arr_u32, Json};

/// A compiled artifact bundle to seed faults into.
struct Bundle {
    ir: LayerIr,
    oim: Oim,
    gdg: GroupDepGraph,
}

fn bundle(design: &str) -> Bundle {
    let d = catalog(design).expect("catalog design");
    let c = compile_design(&d, CompileOpts::default());
    let gdg = GroupDepGraph::build(&c.ir, &c.oim);
    Bundle { ir: c.ir, oim: c.oim, gdg }
}

/// Rebuild the OIM and GDG from a mutated IR, so only the planted IR
/// fault is visible (the splice/GDG passes see consistent artifacts).
fn rebuilt(ir: LayerIr) -> Bundle {
    let oim = Oim::from_ir(&ir);
    let gdg = GroupDepGraph::build(&ir, &oim);
    Bundle { ir, oim, gdg }
}

fn verify(b: &Bundle) -> Report {
    verify_artifacts("seeded", &b.ir, &b.oim, &b.gdg, None)
}

fn verify_parted(b: &Bundle, p: &Partitioning) -> Report {
    verify_artifacts("seeded", &b.ir, &b.oim, &b.gdg, Some(p))
}

#[track_caller]
fn assert_fires(r: &Report, code: &str) {
    let fired: Vec<&str> = r.diags.iter().map(|d| d.code).collect();
    assert!(r.has(code), "expected {code} to fire; fired: {fired:?}");
}

#[track_caller]
fn assert_warns_only(r: &Report, code: &str) {
    assert_fires(r, code);
    let errs: Vec<String> = r.diags.iter().map(|d| d.to_string()).collect();
    assert!(r.is_clean(), "{code} must be a lint, not an error; report: {errs:?}");
}

/// Round-trip a GDG through its JSON form with the reader CSR / writer
/// map rewritten — the only route to those fields, which are private to
/// everything but the serializer and [`GroupDepGraph::reader_csr`].
fn with_reader_csr(
    gdg: &GroupDepGraph,
    offsets: Vec<u32>,
    rows: Vec<u32>,
    writer: Vec<u32>,
) -> GroupDepGraph {
    let mut j = gdg.to_json();
    let Json::Obj(ref mut fields) = j else { panic!("gdg json is an object") };
    fields.insert("reader_offsets".into(), arr_u32(&offsets));
    fields.insert("reader_groups".into(), arr_u32(&rows));
    fields.insert("slot_writer".into(), arr_u32(&writer));
    GroupDepGraph::from_json(&j).expect("mutated gdg json must still deserialize")
}

// ---------------------------------------------------------------------------
// IR01–IR09: IR well-formedness
// ---------------------------------------------------------------------------

#[test]
fn ir01_read_before_write() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    assert!(ir.layers.len() >= 2, "fir8 has a multi-layer schedule");
    let late = ir.layers.last().unwrap()[0].out;
    ir.layers[0][0].a = late; // layer-0 op now reads a slot produced later
    let r = verify(&rebuilt(ir));
    assert_fires(&r, "IR01");
}

#[test]
fn ir02_multi_driver() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    assert!(ir.layers.len() >= 2);
    let dup = ir.layers[0][0].out;
    ir.layers[1][0].out = dup; // second driver for an already-written slot
    let r = verify(&rebuilt(ir));
    assert_fires(&r, "IR02");
}

#[test]
fn ir03_combinational_cycle() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    assert!(ir.layers.len() >= 2);
    let (sa, sb) = (ir.layers[0][0].out, ir.layers[1][0].out);
    ir.layers[0][0].a = sb; // A reads B's out...
    ir.layers[1][0].a = sa; // ...and B reads A's out
    let r = verify(&rebuilt(ir));
    assert_fires(&r, "IR03");
}

#[test]
fn ir04_mask_exceeds_width() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    let (li, oi) = find_narrow_op(&ir).expect("fir8 has a sub-64-bit op");
    ir.layers[li][oi].mask = u64::MAX; // admits bits above the declared width
    let r = verify(&rebuilt(ir));
    assert_fires(&r, "IR04");
}

/// First op whose out slot is declared narrower than 64 bits.
fn find_narrow_op(ir: &LayerIr) -> Option<(usize, usize)> {
    for (li, layer) in ir.layers.iter().enumerate() {
        for (oi, rec) in layer.iter().enumerate() {
            if ir.slot_widths.get(rec.out as usize).is_some_and(|&w| w < 64) {
                return Some((li, oi));
            }
        }
    }
    None
}

#[test]
fn ir05_format_b_order_broken() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    let li = ir
        .layers
        .iter()
        .position(|l| l.len() >= 2)
        .expect("fir8 has a layer with two or more ops");
    ir.layers[li].swap(0, 1); // natural S order no longer ascending
    let r = verify(&rebuilt(ir));
    assert_fires(&r, "IR05");
}

#[test]
fn ir06_out_of_range_operand() {
    let mut b = bundle("fir8");
    // Stale OIM/GDG on purpose: rebuilding from an IR with an out-of-range
    // operand is exactly what the verifier exists to make unnecessary.
    b.ir.layers[0][0].a = (b.ir.num_slots + 5) as u32;
    let r = verify(&b);
    assert_fires(&r, "IR06");
}

#[test]
fn ir07_width_overflow_lint() {
    let mut b = bundle("fir8");
    let rec = *b
        .ir
        .layers
        .iter()
        .flatten()
        .find(|r| r.op == KOp::Add as u8)
        .expect("fir8 sums its taps with adds");
    // 64 + 64 → a 65-bit exact sum: wraps in the u64 slot file.
    b.ir.slot_widths[rec.a as usize] = 64;
    b.ir.slot_widths[rec.b as usize] = 64;
    let r = verify(&b);
    assert_warns_only(&r, "IR07");
}

#[test]
fn ir08_commit_truncation_lint() {
    let mut b = bundle("fir8");
    let ci = b
        .ir
        .commits
        .iter()
        .position(|&(_, _, m)| m.count_ones() < 64)
        .expect("fir8 has a sub-64-bit register");
    let next = b.ir.commits[ci].1;
    b.ir.slot_widths[next as usize] = 64; // next-state wider than the commit keeps
    let r = verify(&b);
    assert_warns_only(&r, "IR08");
}

#[test]
fn ir09_dead_op_lint() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    assert!(ir.layers.len() >= 2, "the dead op must land after its operand's layer");
    let last = ir.layers.len() - 1;
    append_dead_op(&mut ir, last);
    let r = verify(&rebuilt(ir));
    assert_warns_only(&r, "IR09");
}

/// Append a Copy op writing a fresh slot that nothing reads, commits, or
/// outputs. `layer` selects where it lands (an existing index appends to
/// that layer; one past the end opens a new layer — a whole dead group).
fn append_dead_op(ir: &mut LayerIr, layer: usize) {
    let src = ir.layers[0][0];
    let w = ir.slot_widths[src.out as usize];
    let new_slot = ir.num_slots as u32;
    let mut rec = src;
    rec.out = new_slot;
    rec.a = src.out; // written in layer 0, read from any later layer
    rec.op = KOp::Copy as u8;
    rec.arity = 1;
    rec.imm = 0;
    rec.ext = 0;
    rec.aux = 0;
    rec.mask = mask(w);
    ir.num_slots += 1;
    ir.slot_widths.push(w);
    if !ir.slot_names.is_empty() {
        ir.slot_names.push(None);
    }
    if layer < ir.layers.len() {
        ir.layers[layer].push(rec);
    } else {
        ir.layers.push(vec![rec]);
    }
}

// ---------------------------------------------------------------------------
// SP01–SP05: splice / OIM structural audit
// ---------------------------------------------------------------------------

#[test]
fn sp01_layer_shape_mismatch() {
    let mut b = bundle("fir8");
    b.oim.i_payload[0] += 1; // claims an op layer 0 does not have
    let r = verify(&b);
    assert_fires(&r, "SP01");
}

#[test]
fn sp02_operand_coordinate_out_of_range() {
    let mut b = bundle("fir8");
    b.oim.b.r_coords[0] = b.oim.num_slots + 3;
    let r = verify(&b);
    assert_fires(&r, "SP02");
}

#[test]
fn sp03_format_b_disagrees_with_ir() {
    let mut b = bundle("fir8");
    b.oim.b.mask[0] = b.oim.b.mask[0].wrapping_add(1); // field-for-field no more
    let r = verify(&b);
    assert_fires(&r, "SP03");
}

#[test]
fn sp04_format_c_not_stable_sort_of_b() {
    let mut b = bundle("fir8");
    let o = b.oim.c.opcode[0];
    // Any different in-range opcode except MuxChain (whose arity rule
    // would turn this into an SP02 and mask the sort check).
    b.oim.c.opcode[0] = if o == 0 { 1 } else { 0 };
    let r = verify(&b);
    assert_fires(&r, "SP04");
}

#[test]
fn sp05_reader_csr_malformed() {
    let b = bundle("fir8");
    let (offs, rows, sw) = b.gdg.reader_csr();
    let mut offs = offs.to_vec();
    offs.push(*offs.last().unwrap()); // ns + 2 offsets for ns slots
    let gdg = with_reader_csr(&b.gdg, offs, rows.to_vec(), sw.to_vec());
    let r = verify(&Bundle { ir: b.ir, oim: b.oim, gdg });
    assert_fires(&r, "SP05");
}

// ---------------------------------------------------------------------------
// GD01–GD08: group dependency graph soundness
// ---------------------------------------------------------------------------

#[test]
fn gd01_reader_missing_from_csr() {
    let b = bundle("fir8");
    let (offs, rows, sw) = b.gdg.reader_csr();
    let ns = b.ir.num_slots;
    let s = (0..ns)
        .find(|&s| offs[s] < offs[s + 1])
        .expect("some slot has a reader");
    let mut rows = rows.to_vec();
    rows.remove(offs[s] as usize); // drop slot s's first reader
    let mut offs = offs.to_vec();
    for o in offs.iter_mut().skip(s + 1) {
        *o -= 1;
    }
    let gdg = with_reader_csr(&b.gdg, offs, rows, sw.to_vec());
    let r = verify(&Bundle { ir: b.ir, oim: b.oim, gdg });
    assert_fires(&r, "GD01");
}

#[test]
fn gd02_dangling_dependency() {
    let mut b = bundle("fir8");
    b.gdg.group_deps[0].push(9999); // far beyond the group count
    let r = verify(&b);
    assert_fires(&r, "GD02");
}

#[test]
fn gd03_non_topological_dependency() {
    let mut b = bundle("fir8");
    let last = b.gdg.groups.len() - 1;
    b.gdg.group_deps[last].push(last as u32); // dep on itself: not upstream
    let r = verify(&b);
    assert_fires(&r, "GD03");
}

#[test]
fn gd04_groups_do_not_tile_format_c() {
    let mut b = bundle("fir8");
    b.gdg.groups[0].op_end += 1; // overlaps the next group's op range
    let r = verify(&b);
    assert_fires(&r, "GD04");
}

#[test]
fn gd05_slot_writer_mismatch() {
    let b = bundle("fir8");
    let (offs, rows, sw) = b.gdg.reader_csr();
    let mut sw = sw.to_vec();
    let s = sw
        .iter()
        .position(|&g| g != u32::MAX)
        .expect("some slot has a writer");
    sw[s] = u32::MAX; // claims the slot is source-only
    let gdg = with_reader_csr(&b.gdg, offs.to_vec(), rows.to_vec(), sw);
    let r = verify(&Bundle { ir: b.ir, oim: b.oim, gdg });
    assert_fires(&r, "GD05");
}

#[test]
fn gd06_dead_group_lint() {
    let b = bundle("fir8");
    let mut ir = b.ir;
    let nl = ir.layers.len();
    append_dead_op(&mut ir, nl); // a fresh single-op layer → its own group
    let r = verify(&rebuilt(ir));
    assert_warns_only(&r, "GD06");
}

#[test]
fn gd07_phantom_reader_lint() {
    let b = bundle("fir8");
    let (offs, rows, sw) = b.gdg.reader_csr();
    let ns = b.ir.num_slots;
    // An unread slot (the design output qualifies): its CSR row is empty,
    // so listing group 0 there is a phantom with no ordering side effects.
    let s = (0..ns)
        .find(|&s| offs[s] == offs[s + 1])
        .expect("some slot has no readers");
    let mut rows = rows.to_vec();
    rows.insert(offs[s] as usize, 0);
    let mut offs = offs.to_vec();
    for o in offs.iter_mut().skip(s + 1) {
        *o += 1;
    }
    let gdg = with_reader_csr(&b.gdg, offs, rows, sw.to_vec());
    let r = verify(&Bundle { ir: b.ir, oim: b.oim, gdg });
    assert_warns_only(&r, "GD07");
}

#[test]
fn gd08_missing_dependency_edge() {
    let mut b = bundle("fir8");
    let gi = b
        .gdg
        .group_deps
        .iter()
        .position(|d| !d.is_empty())
        .expect("some group depends on another");
    b.gdg.group_deps[gi].remove(0); // the operand that built this edge remains
    let r = verify(&b);
    assert_fires(&r, "GD08");
}

// ---------------------------------------------------------------------------
// PT01–PT07: partition audit
// ---------------------------------------------------------------------------

fn parted(design: &str, n: usize) -> (Bundle, Partitioning) {
    let b = bundle(design);
    let p = partition_ir(&b.ir, n, PartitionerKind::MinCut);
    (b, p)
}

#[test]
fn pt01_owner_vector_malformed() {
    let (b, mut p) = parted("fir8", 2);
    p.owner_of_reg.pop();
    let r = verify_parted(&b, &p);
    assert_fires(&r, "PT01");
}

#[test]
fn pt02_ownership_not_a_disjoint_cover() {
    let (b, mut p) = parted("fir8", 2);
    let reg = p.part_irs[0].commits.first().expect("partition 0 owns a register").0;
    p.part_irs[0].commits.retain(|c| c.0 != reg); // nobody commits it now
    let r = verify_parted(&b, &p);
    assert_fires(&r, "PT02");
}

#[test]
fn pt03_cross_partition_read_not_rum_covered() {
    let (b, mut p) = parted("fir8", 2);
    let t = p
        .tracked
        .iter_mut()
        .find(|t| !t.rum_readers.is_empty())
        .expect("a 2-way split of fir8 has a cross-partition read");
    let victim = *t.rum_readers.last().unwrap();
    t.readers.retain(|&q| q != victim);
    t.rum_readers.retain(|&q| q != victim); // consistent, but the read is uncovered
    let r = verify_parted(&b, &p);
    assert_fires(&r, "PT03");
}

#[test]
fn pt04_rom_in_tracking_table() {
    let (b, mut p) = parted("tiny_cpu_divergent", 2);
    let never = never_written(&b.ir);
    let entry = match (0..b.ir.commits.len()).find(|&ri| never[ri]) {
        // The real fault: a self-committing register (pure ROM) tracked.
        Some(ri) => TrackedReg {
            owner: p.owner_of_reg[ri],
            reg_slot: b.ir.commits[ri].0,
            readers: Vec::new(),
            rum_readers: Vec::new(),
        },
        // Fallback fault, same detector: a tracked slot that is no register.
        None => TrackedReg {
            owner: 0,
            reg_slot: b.ir.layers[0][0].out,
            readers: Vec::new(),
            rum_readers: Vec::new(),
        },
    };
    p.tracked.push(entry);
    let r = verify_parted(&b, &p);
    assert_fires(&r, "PT04");
}

#[test]
fn pt05_targeted_wake_map_disagrees() {
    let (b, mut p) = parted("fir8", 2);
    let slot = *p.readers_of_slot.keys().next().expect("boundary slots exist");
    p.readers_of_slot.remove(&slot); // targeted poke wake would miss it
    let r = verify_parted(&b, &p);
    assert_fires(&r, "PT05");
}

#[test]
fn pt06_outputs_not_on_partition_zero() {
    let (b, mut p) = parted("fir8", 2);
    assert!(!b.ir.output_slots.is_empty(), "fir8 has a design output");
    p.part_irs[0].output_slots.clear();
    let r = verify_parted(&b, &p);
    assert_fires(&r, "PT06");
}

#[test]
fn pt07_phantom_rum_reader_lint() {
    let (b, mut p) = parted("fir8", 3);
    let n = p.num_partitions() as u32;
    let (ti, q) = p
        .tracked
        .iter()
        .enumerate()
        .find_map(|(ti, t)| (0..n).find(|q| !t.readers.contains(q)).map(|q| (ti, q)))
        .expect("some register is not read by every partition");
    let t = &mut p.tracked[ti];
    t.readers.push(q);
    t.readers.sort_unstable();
    if q as usize != t.owner {
        t.rum_readers.push(q);
        t.rum_readers.sort_unstable();
    }
    let r = verify_parted(&b, &p);
    assert_warns_only(&r, "PT07");
}

// ---------------------------------------------------------------------------
// The pristine complement: the compiler's own artifacts are clean
// ---------------------------------------------------------------------------

#[test]
fn pristine_catalog_is_clean() {
    for design in ["counter", "alu32", "fir8", "tiny_cpu_divergent", "rocket_like_1c"] {
        let b = bundle(design);
        let p = partition_ir(&b.ir, 2, PartitionerKind::MinCut);
        let r = verify_artifacts(design, &b.ir, &b.oim, &b.gdg, Some(&p));
        assert!(
            r.is_clean(),
            "pristine {design} must verify clean; got {}: {:?}",
            r.summary(),
            r.diags.iter().map(|d| d.to_string()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn incremental_splice_is_clean() {
    let base = catalog("fir8").expect("catalog design");
    let edited = catalog("fir8_edit").expect("catalog edit variant");
    let mut cache = DesignCache::new(None, 4);
    cache.open_design(&base, true, 2, PartitionerKind::MinCut).expect("base open");
    let (entry, rep) = cache
        .open_design_incremental(&edited, true, 2, PartitionerKind::MinCut)
        .expect("incremental open");
    assert!(rep.incremental, "the edit must take the cone-delta path");
    let p = entry.partitioning();
    let r = verify_artifacts("fir8_edit", &entry.ir, &entry.oim, &entry.gdg, Some(&p));
    assert!(
        r.is_clean(),
        "spliced artifacts must verify clean; got {}: {:?}",
        r.summary(),
        r.diags.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    );
}
