//! Black-box smoke tests of the `rteaal` binary: the `serve --stdio`
//! NDJSON protocol end to end (double-open cache hit, two concurrent
//! packed sessions, checkpoint/restore, and a diff against a plain
//! `rteaal sim` run of the same design), plus the `--vcd` unwritable-
//! path regression (clean CLI error, not a panic and not silence).
//!
//! Session ids are allocated deterministically (0, 1, 2, …), so the
//! whole transcript is scripted up front and replies are read after
//! stdin closes — no interactive turn-taking needed.

use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;
use std::process::{Command, Stdio};

use rteaal::util::json::{self, Json};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rteaal_smoke_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Fetch a required key from a reply object.
fn field<'a>(reply: &'a Json, key: &str) -> &'a Json {
    reply.get(key).unwrap_or_else(|| panic!("reply lacks '{key}': {reply:?}"))
}

fn as_u64(reply: &Json, key: &str) -> u64 {
    field(reply, key).as_u64().unwrap_or_else(|| panic!("'{key}' not a u64: {reply:?}"))
}

/// Parse the `out <name> = 0x…` lines of a `rteaal sim` run.
fn sim_outputs(stdout: &str) -> HashMap<String, u64> {
    let mut outs = HashMap::new();
    for line in stdout.lines() {
        let Some(rest) = line.trim_start().strip_prefix("out ") else { continue };
        let Some((name, value)) = rest.split_once(" = 0x") else { continue };
        outs.insert(name.trim().to_string(), u64::from_str_radix(value.trim(), 16).unwrap());
    }
    outs
}

/// The `out` object of a poll record, decoded to numeric port values.
fn record_outputs(record: &Json) -> HashMap<String, u64> {
    let mut outs = HashMap::new();
    for (name, v) in field(record, "out").as_obj().expect("record 'out' is an object") {
        let hex = v.as_str().expect("port value is a hex string");
        let hex = hex.strip_prefix("0x").expect("port value starts with 0x");
        outs.insert(name.clone(), u64::from_str_radix(hex, 16).unwrap());
    }
    outs
}

#[test]
fn serve_stdio_transcript_smoke() {
    let dir = tmp_dir("serve");
    let snap = dir.join("smoke.rtal");
    let snap_str = snap.display().to_string();
    let cache_dir = dir.join("cache").display().to_string();

    // Two same-design sessions pack onto one 4-lane host; both run 40
    // design cycles, session 0 is checkpointed and restored as session
    // 2, and both continue 5 more cycles.
    let transcript = [
        r#"{"id":1,"verb":"open","design":"fir8","lanes":4,"width":1}"#.to_string(),
        r#"{"id":2,"verb":"open","design":"fir8","lanes":4,"width":1}"#.to_string(),
        r#"{"id":3,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":40}}"#
            .to_string(),
        r#"{"id":4,"verb":"submit","session":1,"stimulus":{"kind":"design","cycles":40}}"#
            .to_string(),
        r#"{"id":5,"verb":"poll","session":0}"#.to_string(),
        r#"{"id":6,"verb":"poll","session":1}"#.to_string(),
        format!(r#"{{"id":7,"verb":"checkpoint","session":0,"path":"{snap_str}"}}"#),
        format!(r#"{{"id":8,"verb":"restore","path":"{snap_str}"}}"#),
        r#"{"id":9,"verb":"submit","session":0,"stimulus":{"kind":"design","cycles":5}}"#
            .to_string(),
        r#"{"id":10,"verb":"submit","session":2,"stimulus":{"kind":"design","cycles":5}}"#
            .to_string(),
        r#"{"id":11,"verb":"poll","session":0}"#.to_string(),
        r#"{"id":12,"verb":"poll","session":2}"#.to_string(),
        r#"{"id":13,"verb":"stats"}"#.to_string(),
        r#"{"id":14,"verb":"close","session":0}"#.to_string(),
        r#"{"id":15,"verb":"poll","session":0}"#.to_string(),
    ];

    let mut child = Command::new(env!("CARGO_BIN_EXE_rteaal"))
        .args(["serve", "--stdio", "--cache-dir", &cache_dir])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    child
        .stdin
        .take()
        .unwrap()
        .write_all((transcript.join("\n") + "\n").as_bytes())
        .unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "serve exited with {:?}: {}", out.status, String::from_utf8_lossy(&out.stderr));

    let stdout = String::from_utf8(out.stdout).unwrap();
    let replies: Vec<Json> = stdout.lines().map(|l| json::parse(l).unwrap()).collect();
    assert_eq!(replies.len(), transcript.len(), "one reply per request");
    let reply = |id: u64| {
        replies
            .iter()
            .find(|r| r.get("id").and_then(Json::as_u64) == Some(id))
            .unwrap_or_else(|| panic!("no reply with id {id}"))
    };
    for id in [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14] {
        assert_eq!(field(reply(id), "ok"), &Json::Bool(true), "request {id} failed");
    }

    // Double open: first is a compile miss, second a memory hit on the
    // same host (packed).
    let (r1, r2) = (reply(1), reply(2));
    assert_eq!(as_u64(r1, "session"), 0);
    assert_eq!(as_u64(r2, "session"), 1);
    assert_eq!(field(field(r1, "cache"), "hit"), &Json::Bool(false));
    assert_eq!(field(field(r2, "cache"), "hit"), &Json::Bool(true));
    assert_eq!(field(field(r2, "cache"), "source"), &Json::Str("memory".into()));
    assert_eq!(as_u64(r1, "host"), as_u64(r2, "host"), "same-design sessions should pack");

    // Two concurrent sessions produce identical per-cycle records.
    let (r5, r6) = (reply(5), reply(6));
    assert_eq!(field(r5, "done"), &Json::Bool(true));
    assert_eq!(field(r5, "cycles"), field(r6, "cycles"), "packed sessions diverged");
    assert_eq!(field(r5, "cycles").as_arr().unwrap().len(), 40);

    // Checkpoint at cycle 40, restored as session 2 at the same cycle.
    assert!(as_u64(reply(7), "bytes") > 0);
    assert_eq!(as_u64(reply(7), "cycle"), 40);
    assert_eq!(as_u64(reply(8), "session"), 2);
    assert_eq!(as_u64(reply(8), "cycle"), 40);

    // The restored session's continuation matches the uninterrupted one.
    let (r11, r12) = (reply(11), reply(12));
    assert_eq!(field(r11, "cycles"), field(r12, "cycles"), "restore diverged");
    assert_eq!(as_u64(r11, "cycle"), 45);

    let r13 = reply(13);
    assert!(as_u64(field(r13, "cache"), "mem_hits") >= 1);
    assert_eq!(as_u64(field(r13, "cache"), "misses"), 1);

    assert_eq!(as_u64(reply(14), "closed"), 0);
    let r15 = reply(15);
    assert_eq!(field(r15, "ok"), &Json::Bool(false));
    assert_eq!(field(field(r15, "error"), "code"), &Json::Str("unknown-session".into()));

    // Differential check against the plain CLI: lane 0 of the service
    // equals a solo `rteaal sim` run of the same design and cycle count.
    let solo = Command::new(env!("CARGO_BIN_EXE_rteaal"))
        .args(["sim", "--design", "fir8", "--cycles", "45", "--kernel", "PSU"])
        .output()
        .unwrap();
    assert!(solo.status.success());
    let solo_outs = sim_outputs(&String::from_utf8(solo.stdout).unwrap());
    assert!(!solo_outs.is_empty(), "no outputs parsed from `rteaal sim`");
    let last = field(r11, "cycles").as_arr().unwrap().last().unwrap();
    assert_eq!(as_u64(last, "cycle"), 45);
    assert_eq!(record_outputs(last), solo_outs, "serve lane 0 != `rteaal sim`");
}

/// Satellite regression: an unwritable `--vcd` target is a clean CLI
/// error (nonzero exit, `error:` on stderr), not a panic and not a
/// silently-absent waveform.
#[test]
fn sim_vcd_unwritable_path_is_a_clean_error() {
    let bad = format!("/nonexistent_rteaal_dir_{}/x.vcd", std::process::id());
    let out = Command::new(env!("CARGO_BIN_EXE_rteaal"))
        .args(["sim", "--design", "counter", "--cycles", "4", "--vcd", &bad])
        .output()
        .unwrap();
    assert!(!out.status.success(), "unwritable --vcd target must fail the run");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error:"), "stderr lacks a clean error: {stderr}");
    assert!(!stderr.contains("panicked"), "CLI panicked instead of erroring: {stderr}");
}
